"""Shared knob for the --check smoke mode (benchmarks/run.py).

``python -m benchmarks.run --check`` sets ``SOSA_BENCH_CHECK=1`` before
importing the suites; suites call ``pick(full, tiny)`` on their expensive
knobs (workload sizes, sweep grids, repeat counts) so the smoke pass
exercises every row-emitting code path in seconds. Numbers produced under
check mode are NOT benchmark results — the mode exists to assert that
every suite still runs end to end (each emits its ``_total`` row and no
``ERROR`` rows), as part of the documented fast gate.
"""

from __future__ import annotations

import os


def check_mode() -> bool:
    return os.environ.get("SOSA_BENCH_CHECK") == "1"


def pick(full, tiny):
    """`full` normally; `tiny` under --check."""
    return tiny if check_mode() else full
