"""Paper Fig 5: isopower design-space maps (CNN-only / Transformer-only /
mixed) + the paper's headline optima (66x32 / 20x128 / ~20-32x32)."""

from __future__ import annotations

import time

from repro.core.dse import best_point, sweep
from repro.core.workloads import dse_cnn_suite, dse_transformer_suite


def bench() -> list[str]:
    lines = []
    rows = (8, 16, 20, 32, 48, 64, 66, 128, 256)
    cols = (8, 16, 32, 64, 128, 256)
    cnn = dse_cnn_suite()
    tfm = dse_transformer_suite()
    mixed = {**cnn, **tfm}
    for name, suite, paper_opt in (("cnn", cnn, "66x32"),
                                   ("transformer", tfm, "20x128"),
                                   ("mixed", mixed, "20x32..32x32")):
        t0 = time.time()
        pts = sweep(suite, rows, cols)
        us = (time.time() - t0) * 1e6 / len(pts)
        best = best_point(pts)
        lines.append(
            f"dse/{name},{us:.0f},best={best.rows}x{best.cols};"
            f"eff={best.effective_tops_at_tdp:.1f};paper_best={paper_opt}")
        # square-vs-best comparison (the paper's non-square claim)
        sq = {(p.rows, p.cols): p for p in pts}
        for r in (32, 128):
            if (r, r) in sq:
                p = sq[(r, r)]
                lines.append(
                    f"dse/{name}/{r}x{r},{us:.0f},"
                    f"eff={p.effective_tops_at_tdp:.1f};"
                    f"vs_best={p.effective_tops_at_tdp / max(1e-9, best.effective_tops_at_tdp):.2f}")
    return lines
