"""Paper Fig 5: isopower design-space maps (CNN-only / Transformer-only /
mixed) + the paper's headline optima (66x32 / 20x128 / ~20-32x32).

Also reports the batched-vs-scalar engine comparison: the same mixed Fig-5
grid through `sweep` (one analyze_batch call) and `sweep_scalar` (the
original per-point Python loop), as a `dse/engine_speedup` CSV row.
"""

from __future__ import annotations

import time

from repro.core.dse import best_point, sweep, sweep_scalar
from repro.core.workloads import dse_cnn_suite, dse_transformer_suite

from ._check import pick

FIG5_ROWS = (8, 16, 20, 32, 48, 64, 66, 128, 256)
FIG5_COLS = (8, 16, 32, 64, 128, 256)


def bench() -> list[str]:
    grid_rows = pick(FIG5_ROWS, (20, 32, 66))
    grid_cols = pick(FIG5_COLS, (32, 128))
    lines = []
    cnn = dse_cnn_suite()
    tfm = dse_transformer_suite()
    mixed = {**cnn, **tfm}
    for name, suite, paper_opt in (("cnn", cnn, "66x32"),
                                   ("transformer", tfm, "20x128"),
                                   ("mixed", mixed, "20x32..32x32")):
        t0 = time.time()
        pts = sweep(suite, grid_rows, grid_cols)
        us = (time.time() - t0) * 1e6 / len(pts)
        best = best_point(pts)
        lines.append(
            f"dse/{name},{us:.0f},best={best.rows}x{best.cols};"
            f"eff={best.effective_tops_at_tdp:.1f};paper_best={paper_opt}")
        # square-vs-best comparison (the paper's non-square claim)
        sq = {(p.rows, p.cols): p for p in pts}
        for r in (32, 128):
            if (r, r) in sq:
                p = sq[(r, r)]
                lines.append(
                    f"dse/{name}/{r}x{r},{us:.0f},"
                    f"eff={p.effective_tops_at_tdp:.1f};"
                    f"vs_best={p.effective_tops_at_tdp / max(1e-9, best.effective_tops_at_tdp):.2f}")

    # engine comparison on the mixed Fig-5 grid: batched vs scalar wall time
    t0 = time.time()
    pts_b = sweep(mixed, grid_rows, grid_cols)
    t_batched = time.time() - t0
    t0 = time.time()
    pts_s = sweep_scalar(mixed, grid_rows, grid_cols)
    t_scalar = time.time() - t0
    bb, bs = best_point(pts_b), best_point(pts_s)
    agree = (bb.rows, bb.cols) == (bs.rows, bs.cols)
    lines.append(
        f"dse/engine_speedup,{t_batched * 1e6:.0f},"
        f"scalar_ms={t_scalar * 1e3:.0f};batched_ms={t_batched * 1e3:.0f};"
        f"speedup={t_scalar / max(1e-9, t_batched):.1f}x;"
        f"best_agree={agree}")
    return lines
