"""Paper Table 2 + Fig 9: array granularity vs effective throughput @400W.

Table 2 goes through the batched `table2_rows` (one analyze_batch call over
the six designs); Fig 9's per-model breakdown reads individual (design,
workload) cells out of a single batched grid instead of looping
evaluate_design per model.
"""

from __future__ import annotations

import time

from repro.core.dse import build_design_vector, table2_rows
from repro.core.simulator import analyze_batch, pack_workloads
from repro.core.workloads import full_suite

PAPER_TABLE2 = {  # (rows, cols) -> (util, effective TOPS @400W)
    (512, 512): (0.103, 191.3), (256, 256): (0.140, 183.0),
    (128, 128): (0.138, 205.0), (64, 64): (0.174, 200.9),
    (16, 16): (0.400, 198.9), (32, 32): (0.394, 317.4),
}


def bench() -> list[str]:
    lines = []
    suite = full_suite(batch=1)
    t0 = time.time()
    rows = table2_rows(suite)
    dt_us = (time.time() - t0) * 1e6 / max(1, len(rows))
    best = max(rows, key=lambda p: p.effective_tops_at_tdp)
    for p in rows:
        pu, pe = PAPER_TABLE2[(p.rows, p.cols)]
        lines.append(
            f"granularity/{p.rows}x{p.cols},{dt_us:.0f},"
            f"eff_tops={p.effective_tops_at_tdp:.1f};util={p.utilization:.3f};"
            f"paper_eff={pe};paper_util={pu}")
    lines.append(f"granularity/best,{dt_us:.0f},"
                 f"{best.rows}x{best.cols}_eff={best.effective_tops_at_tdp:.1f}")
    # Fig 9: per-model breakdown at the paper's two headline points — one
    # batched (2 designs x 10 models) grid, per-cell reads
    t0 = time.time()
    packed = pack_workloads(suite)
    batch = analyze_batch(packed, build_design_vector(
        [(32, 32, "butterfly-2", 256), (128, 128, "butterfly-2", 32)]))
    dt_us = (time.time() - t0) * 1e6 / (2 * len(batch.names))  # per cell
    for w, name in enumerate(batch.names):
        e32 = float(batch.effective_tops_at_tdp[0, w])
        e128 = float(batch.effective_tops_at_tdp[1, w])
        lines.append(
            f"granularity/fig9/{name},{dt_us:.0f},"
            f"eff32x32={e32:.1f};eff128x128={e128:.1f};"
            f"ratio={e32 / max(1e-9, e128):.2f}")
    return lines
