"""Paper Table 1 + Fig 12a: interconnect comparison.

Busy-pods / cycles-per-tile come from the slice-accurate scheduler with the
functional Butterfly-k router (exact edge conflicts); mW/byte from the
calibrated stage model. Run at 64 pods on a CNN+BERT mix to keep the
cycle-accurate Python scheduler fast; ratios are the paper's subject.
"""

from __future__ import annotations

import time

from repro.core import ArrayConfig, AcceleratorConfig, simulate
from repro.core.simulator import icn_spec_for
from repro.core.workloads import bert, resnet

from ._check import pick

PAPER_TABLE1 = {  # type -> (busy %, cycles/tile, mW/B) at 256 pods
    "butterfly-1": (66.81, 19.72, 0.23), "butterfly-2": (72.41, 20.17, 0.52),
    "butterfly-4": (72.26, 20.27, 1.15), "butterfly-8": (72.43, 20.48, 2.53),
    "crossbar": (72.38, 19.73, 7.36), "benes": (72.38, 30.00, 0.92),
}


def bench(pods: int | None = None) -> list[str]:
    from repro.core.simulator import merge_workloads
    pods = pods or pick(256, 16)
    # batch-4 mix: enough parallel tiles to load 256 pods (the paper
    # averages across its full benchmark suite)
    wl = merge_workloads(resnet(50, 224, batch=2), bert("base", 100, batch=2))
    wl = wl[:pick(len(wl), 12)]
    lines = []
    for icn in pick(("butterfly-1", "butterfly-2", "butterfly-4",
                     "butterfly-8", "crossbar", "benes"),
                    ("butterfly-2", "crossbar")):
        accel = AcceleratorConfig(
            array=ArrayConfig(32, 32), num_pods=pods,
            icn_mw_per_byte=icn_spec_for(icn, 256).mw_per_byte)
        t0 = time.time()
        r = simulate(wl, accel, interconnect=icn)
        us = (time.time() - t0) * 1e6
        pb, pc, pm = PAPER_TABLE1[icn]
        mw = icn_spec_for(icn, 256).mw_per_byte
        lines.append(
            f"interconnect/{icn},{us:.0f},"
            f"busy={100 * r.busy_pods:.1f}%;cyc_tile={r.cycles_per_tile:.1f};"
            f"mw_b={mw:.2f};eff_tops={r.effective_tops_at_tdp:.1f};"
            f"paper=({pb},{pc},{pm})")
    return lines
