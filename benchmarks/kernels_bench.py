"""Kernel microbenchmarks (interpret mode on CPU — correctness-shaped
timings only; the BlockSpec geometry and VMEM working sets reported here
are the TPU-relevant outputs)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd.ops import ssd
from repro.kernels.systolic_gemm.ops import systolic_gemm
from repro.parallel.autoshard import choose_blocks

from ._check import pick


def _time(fn, *args, n=3, warmup=1, **kw):
    """Steady-state timing: warm (compile) calls first, then min-of-n with
    every call blocked to completion — async dispatch otherwise overlaps
    the loop and only the last call's device time is ever observed."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench() -> list[str]:
    rng = np.random.default_rng(0)
    lines = []

    M = K = N = pick(512, 256)
    x8 = jnp.asarray(rng.integers(-100, 100, (M, K)), jnp.int8)
    w8 = jnp.asarray(rng.integers(-100, 100, (K, N)), jnp.int8)
    us = _time(systolic_gemm, x8, w8, interpret=True)
    us_ref = _time(lambda a, b: jnp.dot(a.astype(jnp.int32),
                                        b.astype(jnp.int32)), x8, w8)
    bm, bn, bk = choose_blocks(M, K, N, dtype_bytes=1)
    vmem_kb = (2 * (bm * bk + bk * bn) * 1 + bm * bn * (4 + 4)) / 1024
    lines.append(f"kernels/systolic_gemm_int8_{M},{us:.0f},"
                 f"jnp_ref_us={us_ref:.0f};blocks={bm}x{bn}x{bk};"
                 f"vmem_kb={vmem_kb:.0f}")

    B, S, H, D = 1, pick(256, 128), 4, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, 2, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, 2, D)), jnp.float32)
    us = _time(flash_attention, q, k, v, block_q=128, block_k=128,
               interpret=True)
    lines.append(f"kernels/flash_attn_s{S},{us:.0f},"
                 f"blocks=128x128;vmem_kb="
                 f"{(128 * D * 4 * 2 + 128 * D * 4) / 1024:.0f}")

    b, S2, H2, P, Nn = 1, pick(256, 128), 4, 32, 64
    xs = jnp.asarray(rng.standard_normal((b, S2, H2, P)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S2, H2)) * 0.3 + 0.1, jnp.float32)
    A = jnp.asarray(-rng.random(H2) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, S2, 1, Nn)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, S2, 1, Nn)), jnp.float32)
    Dm = jnp.asarray(rng.random(H2), jnp.float32)
    us = _time(lambda *a: ssd(*a, chunk=64, interpret=True)[0],
               xs, dt, A, Bm, Cm, Dm)
    lines.append(f"kernels/ssd_s{S2},{us:.0f},chunk=64;"
                 f"state_scratch_kb={P * Nn * 4 / 1024:.0f}")

    # ABFT guard overhead at the steady-state decode shape: M fused lanes
    # against a [K, N] weight. The checksum envelope's extra work is one
    # row of A, one column of B and the O(MN) verify — analytically
    # ~(1/M + 1/N) of the GEMM; the wall ratio here is interpret-mode
    # (correctness-shaped) but both sides pay the same backend, so the
    # ratio tracks the FLOP ratio. Standalone guarded_gemm (no GuardTape)
    # is pure, so jitting it for steady-state timing is safe.
    from repro.kernels.systolic_gemm.guard import PodGuard, guarded_gemm
    Md, Kd, Nd = 64, pick(512, 256), pick(512, 256)
    xd = jnp.asarray(rng.standard_normal((Md, Kd)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((Kd, Nd)), jnp.float32)
    plain = jax.jit(lambda a, b: systolic_gemm(a, b, interpret=True))
    timings = {"plain": _time(plain, xd, wd)}
    for mode in ("probe", "abft"):
        g = PodGuard(mode=mode)
        fn = jax.jit(lambda a, b, g=g: guarded_gemm(a, b, guard=g,
                                                    interpret=True))
        timings[mode] = _time(fn, xd, wd)
    analytic = 1.0 / Md + 1.0 / Nd + 1.0 / (Md * Nd)
    lines.append(
        f"kernels/abft_overhead_m{Md}k{Kd}n{Nd},{timings['abft']:.0f},"
        f"plain_us={timings['plain']:.0f};probe_us={timings['probe']:.0f};"
        f"abft_over_plain={timings['abft'] / timings['plain']:.2f}x;"
        f"probe_over_plain={timings['probe'] / timings['plain']:.2f}x;"
        f"analytic_checksum_flops={analytic * 100:.1f}%")
    return lines
