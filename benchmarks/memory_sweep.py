"""Paper Fig 13 + §6.4: SRAM bank size vs DRAM traffic / effective
throughput (ResNet-152 batch 8, the largest working set in the suite).

Model: per-level working set = live activation tiles + double-buffered
weights; overflow beyond the on-chip SRAM (banks x size) spills to HBM at
DRAM_BW, stretching the level's execution time.
"""

from __future__ import annotations

import time

from repro.core import ArrayConfig, AcceleratorConfig, analyze
from repro.core.simulator import _levels
from repro.core.workloads import resnet

DRAM_BW = 700e9   # HBM, TPUv3-like (§5)


def bench(pods: int = 256) -> list[str]:
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=pods)
    wl = resnet(152, 299, batch=8)
    base = analyze(wl, accel)
    lines = []
    t0 = time.time()
    for bank_kb in (64, 128, 256, 512, 1024):
        sram = pods * bank_kb * 1024
        spill = 0.0
        compute_s = base.total_cycles / 1e9
        for level in _levels(wl):
            ws = 0
            for g in level:
                ws += g.d1 * g.d2 + 2 * g.d2 * g.d3 + 2 * g.d1 * g.d3
            spill += max(0, ws - sram)
        dram_s = spill / DRAM_BW
        eff = base.effective_tops_at_tdp * compute_s / (compute_s + dram_s)
        us = (time.time() - t0) * 1e6
        lines.append(
            f"memory/bank{bank_kb}kB,{us:.0f},"
            f"eff_rel={eff / base.effective_tops_at_tdp:.3f};"
            f"dram_gb={spill / 1e9:.1f}")
    return lines
