"""Paper Fig 13 + §6.4: SRAM bank size vs DRAM traffic / effective
throughput (ResNet-152 batch 8, the largest working set in the suite).

Model: per-level working set = live activation tiles + double-buffered
weights; overflow beyond the on-chip SRAM (banks x size) spills to HBM at
DRAM_BW, stretching the level's execution time.

Since PR 2 the per-level loop is vectorized on the batched engine: the
compute side of the whole (bank-size x design) grid is ONE analyze_batch
call, and the working-set / spill side is the per-segment arrays already
living on PackedWorkloads (level_working_set_bytes + sram_spill_bytes) —
the ROADMAP's "memory sweep on the same engine" item.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import analyze_batch, pack_workloads, sram_spill_bytes
from repro.core.dse import build_design_vector
from repro.core.workloads import resnet

DRAM_BW = 700e9   # HBM, TPUv3-like (§5)
BANK_KB = (64, 128, 256, 512, 1024)


def bench(pods: int = 256) -> list[str]:
    designs = [(32, 32, "butterfly-2", pods),
               (64, 64, "butterfly-2", pods // 4)]
    packed = pack_workloads({"resnet152@8": resnet(152, 299, batch=8)})
    t0 = time.time()
    batch = analyze_batch(packed, build_design_vector(designs))
    bank_b = np.asarray(BANK_KB, dtype=np.float64) * 1024.0
    lines = []
    for p, (r, c, _, n_pods) in enumerate(designs):
        compute_s = float(batch.total_cycles[p, 0]) / 1e9
        eff_base = float(batch.effective_tops_at_tdp[p, 0])
        spill = sram_spill_bytes(packed, n_pods * bank_b)[:, 0]  # (B,)
        dram_s = spill / DRAM_BW
        eff_rel = compute_s / (compute_s + dram_s)
        us = (time.time() - t0) * 1e6 / (len(designs) * len(BANK_KB))
        tag = "" if p == 0 else f"{r}x{c}/"
        for kb, rel, gb in zip(BANK_KB, eff_rel, spill / 1e9):
            lines.append(
                f"memory/{tag}bank{kb}kB,{us:.0f},"
                f"eff_rel={rel:.3f};dram_gb={gb:.1f}")
        assert eff_base > 0  # grid sanity: the analyze side produced cells
    return lines
