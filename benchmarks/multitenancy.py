"""Paper Fig 11 + §6.1: batch scaling and multi-tenancy.

ResNet saturates the pods alone; BERT (seq 100) starves 256 pods at batch 1
and scales with batch; running them *co-scheduled* recovers the idle slots —
the paper reports 1.44x over sequential execution on 256 pods.

Since PR 2 this rides the repro.tenancy subsystem: the whole
(pod-count x batch) Fig-11 grid is one batched planner call
(tenancy.sweep.fig11_sweep -> simulator.analyze_batch), the scalar
merge_workloads + analyze_scalar path stays as the oracle
(tenancy.planner.plan_mix_scalar), and the slice-accurate SliceScheduler
cross-checks the analytical gain at a sim-tractable pod count. Each phase
is timed separately (the us column is per-phase, not cumulative).
"""

from __future__ import annotations

import time

from repro.core import AcceleratorConfig, ArrayConfig, simulate
from repro.tenancy import fig11_mixes, fig11_sweep, plan_mix_scalar

from ._check import pick

_BATCHES = (1, 2, 4, 8)


def bench(pods: int = 256) -> list[str]:
    lines = []
    pods_axis = tuple(sorted({128, pods}))  # 128 = sim-tractable gain cell

    # phase 1 — the batched Fig-11 grid (one analyze_batch for all cells)
    t0 = time.time()
    grid = fig11_sweep(pods=pods_axis, batches=_BATCHES)
    us_cell = (time.time() - t0) * 1e6 / (len(pods_axis) * len(_BATCHES))
    for p, row in zip(pods_axis, grid):
        for plan in row:
            lines.append(
                f"multitenancy/pods{p}/{plan.mix},{us_cell:.0f},"
                f"eff_tops={plan.effective_tops_at_tdp:.1f};"
                f"seq_tops={plan.sequential_effective_tops:.1f};"
                f"gain={plan.parallel_gain:.2f}x;"
                f"fairness={plan.fairness:.3f};paper=1.44x")

    # phase 2 — scalar merge_workloads + analyze_scalar oracle on the
    # headline cell (timed on its own; also the agreement gate)
    mix = fig11_mixes(batches=(1,))[0]
    t0 = time.time()
    sc = plan_mix_scalar(mix, (32, 32, "butterfly-2", pods))
    us_scalar = (time.time() - t0) * 1e6
    b = grid[pods_axis.index(pods)][0]
    agree = abs(b.effective_tops_at_tdp - sc.effective_tops_at_tdp) \
        <= 1e-6 * sc.effective_tops_at_tdp
    lines.append(
        f"multitenancy/scalar_oracle,{us_scalar:.0f},"
        f"eff_tops={sc.effective_tops_at_tdp:.1f};batched_agrees={agree}")

    # phase 3 — slice-accurate cross-check at a sim-tractable pod count:
    # sequential and merged runs timed separately (they ARE the two
    # phases being compared; the old bench stamped one cumulative time on
    # every line)
    accel_s = AcceleratorConfig(array=ArrayConfig(32, 32),
                                num_pods=pick(128, 16))
    cap = pick(None, 8)  # --check: slice-sim a bounded stream prefix
    streams = [list(t.gemms)[:cap] for t in mix.tenants
               for _ in range(t.replicas)]
    t0 = time.time()
    seq = [simulate(wl, accel_s) for wl in streams]
    us_seq = (time.time() - t0) * 1e6
    seq_cycles = sum(r.total_cycles for r in seq)
    util_seq = sum(r.total_macs for r in seq) / (
        accel_s.num_pods * accel_s.array.num_pe * seq_cycles)
    eff_seq = accel_s.peak_ops_at_tdp * util_seq / 1e12
    merged = mix.merged()
    merged = merged[:pick(len(merged), 16)]
    t0 = time.time()
    par = simulate(merged, accel_s)
    us_par = (time.time() - t0) * 1e6
    lines.append(f"multitenancy/sequential,{us_seq:.0f},eff_tops={eff_seq:.1f}")
    lines.append(f"multitenancy/parallel,{us_par:.0f},"
                 f"eff_tops={par.effective_tops_at_tdp:.1f}")
    analytic = grid[pods_axis.index(128)][0].parallel_gain
    lines.append(f"multitenancy/gain,{us_seq + us_par:.0f},"
                 f"{par.effective_tops_at_tdp / max(1e-9, eff_seq):.2f}x"
                 f";analytic={analytic:.2f}x;paper=1.44x")
    return lines
