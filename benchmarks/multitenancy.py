"""Paper Fig 11 + §6.1: batch scaling and multi-tenancy.

ResNet saturates the pods alone; BERT (seq 100) starves 256 pods at batch 1
and scales with batch; running both *in parallel* recovers the idle slots —
the paper reports 1.44x over sequential execution.
"""

from __future__ import annotations

import time

from repro.core import ArrayConfig, AcceleratorConfig, analyze, merge_workloads
from repro.core.workloads import bert, resnet


def bench(pods: int = 256) -> list[str]:
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=pods)
    lines = []
    t0 = time.time()
    for batch in (1, 2, 4, 8):
        rn = analyze(resnet(152, 299, batch=batch), accel)
        bt = analyze(bert("medium", 100, batch=batch), accel)
        lines.append(f"multitenancy/batch{batch}/resnet152,0,"
                     f"eff_tops={rn.effective_tops_at_tdp:.1f}")
        lines.append(f"multitenancy/batch{batch}/bert-medium,0,"
                     f"eff_tops={bt.effective_tops_at_tdp:.1f}")
    # multi-tenant: resnet + bert co-scheduled vs back-to-back sequential,
    # with the slice-accurate scheduler (the level-barrier analytic model
    # under-reports cross-workload interleaving) at a sim-tractable scale
    from repro.core import simulate
    accel_s = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=128)
    rn = resnet(50, 224)
    bt = bert("medium", 100)
    seq_r = simulate(rn, accel_s)
    seq_b = simulate(bt, accel_s)
    seq_cycles = seq_r.total_cycles + seq_b.total_cycles
    util_seq = (seq_r.total_macs + seq_b.total_macs) / (
        accel_s.num_pods * accel_s.array.num_pe * seq_cycles)
    par = simulate(merge_workloads(rn, bt), accel_s)
    eff_seq = accel_s.peak_ops_at_tdp * util_seq / 1e12
    us = (time.time() - t0) * 1e6
    lines.append(f"multitenancy/sequential,{us:.0f},eff_tops={eff_seq:.1f}")
    lines.append(f"multitenancy/parallel,{us:.0f},"
                 f"eff_tops={par.effective_tops_at_tdp:.1f}")
    lines.append(f"multitenancy/gain,{us:.0f},"
                 f"{par.effective_tops_at_tdp / max(1e-9, eff_seq):.2f}x"
                 f";paper=1.44x")
    return lines
