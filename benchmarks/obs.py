"""Observability suite: the serving telemetry layer (src/repro/obs/).

Rows (`obs/...`):

  * `obs/effective_tops_{prefill,decode}` — the paper's headline metric,
    live: measured tokens/s from the engine's metrics counters, converted
    to useful-MAC throughput over the recorded GEMM timeline and scaled
    by the kernel autotuner's padded-MAC tile utilization. (CPU wall
    clock, so the absolute TOPS are interpret-scale; the row exists so
    the trajectory of the *measured* number is tracked next to the model.)
  * `obs/drift_{prefill,decode}` — predicted (wave model) vs measured
    (slice-accurate scheduler) utilization of the recorded timeline at a
    paper-scale design point; `drift` must stay inside the calibrated
    <=1.55x band (gated by tests/test_obs.py).
  * `obs/trace_export` — Chrome trace-event / Perfetto JSON export timing
    for the run's spans.
  * `obs/metrics_overhead` — wall-clock ratio of a metrics+tracer engine
    run over a bare one on the same workload (the zero-sync claim is
    gated by tests; this row tracks the host-side cost).
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from ._check import pick


def _serve(metrics, tracer, lengths, max_new, model, params):
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine(model, params, slots=4, max_len=64,
                      metrics=metrics, tracer=tracer)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, eng.model.cfg.vocab, int(n),
                                        dtype=np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lengths)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=500)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    return eng, dt


def bench() -> list[str]:
    from repro.configs import get_arch, reduced
    from repro.models.model import Model
    from repro.obs.drift import drift_report, effective_tops_summary
    from repro.obs.export import write_chrome_trace
    from repro.obs.metrics import MetricsRegistry
    from repro.parallel.autoshard import choose_blocks
    from repro.tenancy.trace import ServeTraceRecorder

    lines: list[str] = []
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lengths = pick(list(range(5, 53, 4)), [5, 9])   # 12 lens full / 2 tiny
    max_new = pick(9, 3)

    # warm pass compiles every bucket/chunk variant; the measured pass is
    # the one the telemetry reports (warm + single pass: the obs rows are
    # about the telemetry layer, not a horse race)
    _serve(None, None, lengths, max_new, model, params)
    metrics = MetricsRegistry()
    rec = ServeTraceRecorder()
    _, traced_dt = _serve(metrics, rec, lengths, max_new, model, params)

    # autotune the run's dominant GEMM shapes so the tile-util gauges the
    # effective-TOPS row folds in are the ones serving would use
    for m in (len(lengths), 64):
        choose_blocks(m, cfg.d_model, cfg.d_ff)

    from repro.obs.metrics import registry as global_registry
    eff = effective_tops_summary(rec, cfg, metrics,
                                 kernel_metrics=global_registry())
    for row in eff:
        lines.append(
            f"obs/effective_tops_{row.phase},0,"
            f"tok_s={row.tok_s:.1f};macs_per_tok={row.macs_per_token:.0f};"
            f"tile_util={row.tile_utilization:.3f};"
            f"measured_tops={row.measured_tops:.3e};"
            f"effective_tops={row.effective_tops:.3e}")

    t0 = time.perf_counter()
    drift = drift_report(rec, cfg, metrics=metrics,
                         max_events_per_phase=pick(32, 4))
    drift_us = (time.perf_counter() - t0) * 1e6 / max(1, len(drift))
    for row in drift:
        lines.append(
            f"obs/drift_{row.phase},{drift_us:.0f},"
            f"events={row.events};gemms={row.gemms};"
            f"predicted_util={row.predicted_utilization:.4f};"
            f"measured_util={row.measured_utilization:.4f};"
            f"drift={row.drift:.3f}x;"
            f"predicted_eff_tops={row.predicted_effective_tops:.2f};"
            f"measured_eff_tops={row.measured_effective_tops:.2f}")

    path = os.path.join(tempfile.mkdtemp(prefix="sosa-obs-"), "trace.json")
    t0 = time.perf_counter()
    n_spans = write_chrome_trace(path, rec.spans)
    export_us = (time.perf_counter() - t0) * 1e6
    n_events = len(json.load(open(path))["traceEvents"])
    lines.append(f"obs/trace_export,{export_us:.0f},"
                 f"spans={n_spans};trace_events={n_events};"
                 f"bytes={os.path.getsize(path)}")

    # telemetry overhead: same warm workload, bare engine vs instrumented
    _, bare_dt = _serve(None, None, lengths, max_new, model, params)
    snap = metrics.snapshot()
    n_series = sum(len(snap[k]) for k in ("counters", "gauges", "histograms"))
    lines.append(f"obs/metrics_overhead,0,"
                 f"traced_s={traced_dt:.3f};bare_s={bare_dt:.3f};"
                 f"overhead={traced_dt / bare_dt:.3f}x;"
                 f"series={n_series}")
    return lines
