"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only granularity,...]
                                            [--json BENCH_foo.json]
                                            [--check]

The ``dse`` suite emits a ``dse/engine_speedup`` row comparing the batched
analytical engine (core.dse.sweep -> simulator.analyze_batch) against the
original scalar loop (core.dse.sweep_scalar) on the Fig-5 mixed grid; the
``serving`` suite compares the bucketed + fused ServeEngine hot loop
against the seed per-token engine (compile counts, tokens/s, p50/p99);
the ``obs`` suite reports the serving telemetry layer (effective-TOPS,
predicted-vs-measured drift, trace-export timing — src/repro/obs/).

``--json`` additionally writes the rows as a machine-readable
``BENCH_*.json`` (schema ``sosa-bench-v1``) so the perf trajectory is
recorded across PRs.

``--check`` is the CI smoke mode (part of the documented fast gate): it
runs every suite at tiny shapes (suites read ``benchmarks._check.
check_mode()``), then asserts that each selected suite emitted its
``_total`` row and no ``ERROR`` rows, exiting non-zero otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

SCHEMA = "sosa-bench-v1"
ROW_FIELDS = ("suite", "name", "us_per_call", "derived")


def parse_row(line: str) -> dict:
    """One CSV row -> record. `derived` may itself contain ';'-separated
    key=value pairs; it is kept verbatim (strings stay greppable, commas
    included) and the row is split on the first two commas only."""
    name, us, derived = line.split(",", 2)
    suite = name.split("/", 1)[0]
    try:
        us_val = float(us)
    except ValueError:
        us_val = 0.0
    return {"suite": suite, "name": name, "us_per_call": us_val,
            "derived": derived}


def error_row(suite: str, exc: BaseException) -> str:
    """The ``SUITE/ERROR`` row: exception type and message as greppable
    ``derived`` key=value pairs (newlines flattened; commas survive —
    parse_row keeps everything past the second comma verbatim)."""
    msg = " ".join(str(exc).split()) or "<no message>"
    return (f"{suite}/ERROR,0,"
            f"error_type={type(exc).__name__};error_msg={msg}")


def validate_doc(doc: dict) -> list[str]:
    """Validate a BENCH_*.json document against the sosa-bench-v1 schema
    (BENCH.md); returns a list of problems (empty = valid)."""
    problems: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(doc.get("created_unix"), (int, float)) \
            or doc.get("created_unix", 0) <= 0:
        problems.append("created_unix missing or not a positive number")
    if not isinstance(doc.get("argv"), list) \
            or not all(isinstance(a, str) for a in doc.get("argv", [])):
        problems.append("argv missing or not a list of strings")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return problems + ["rows missing or empty"]
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or set(ROW_FIELDS) - set(row):
            problems.append(f"rows[{i}]: missing fields "
                            f"{sorted(set(ROW_FIELDS) - set(row or {}))}")
            continue
        if not isinstance(row["name"], str) \
                or row["name"].split("/", 1)[0] != row["suite"]:
            problems.append(
                f"rows[{i}]: name {row.get('name')!r} does not start with "
                f"suite {row.get('suite')!r}")
        if not isinstance(row["us_per_call"], (int, float)) \
                or row["us_per_call"] < 0:
            problems.append(f"rows[{i}]: us_per_call not a number >= 0")
        if not isinstance(row["derived"], str):
            problems.append(f"rows[{i}]: derived not a string")
    suites = {r["suite"] for r in rows if isinstance(r, dict)
              and isinstance(r.get("suite"), str)}
    for s in sorted(suites):
        if not any(isinstance(r, dict) and r.get("name") == f"{s}/_total"
                   for r in rows):
            problems.append(f"suite {s!r} has no _total row")
    return problems


def check_rows(rows: list[dict], expected_suites: list[str]) -> list[str]:
    """The --check assertions: every selected suite emitted its ``_total``
    row and no suite emitted an ``ERROR`` row. Returns problems."""
    problems: list[str] = []
    names = {r["name"] for r in rows}
    for s in expected_suites:
        if f"{s}/_total" not in names:
            problems.append(f"suite {s!r} emitted no _total row")
    for r in rows:
        if r["name"].endswith("/ERROR"):
            problems.append(f"{r['suite']}: ERROR row — {r['derived']}")
    return problems


def write_json(rows: list[dict], path: str) -> None:
    """BENCH_*.json schema: header + the parsed rows."""
    doc = {
        "schema": SCHEMA,
        "created_unix": time.time(),
        "argv": sys.argv[1:],
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def load_suites() -> dict:
    """The suite registry, resolved at call time (suites consult
    ``benchmarks._check.check_mode()`` at import, so --check must set the
    env var first). A separate hook so the --check regression test can
    substitute a failing suite and assert the nonzero exit."""
    from benchmarks import (dse_map, granularity, interconnect, kernels_bench,
                            memory_sweep, multitenancy, obs, scaling, serving,
                            tenancy, tiling_sweep)
    return {
        "granularity": granularity.bench,       # Table 2 + Fig 9
        "interconnect": interconnect.bench,     # Table 1 + Fig 12a
        "tiling": tiling_sweep.bench,           # Fig 12b
        "dse": dse_map.bench,                   # Fig 5
        "multitenancy": multitenancy.bench,     # Fig 11
        "tenancy": tenancy.bench,               # tenant-mix DSE (repro.tenancy)
        "memory": memory_sweep.bench,           # Fig 13
        "scaling": scaling.bench,               # Fig 10
        "kernels": kernels_bench.bench,         # §4.1 pod microarchitecture
        "serving": serving.bench,               # hot-loop engine vs seed
        "obs": obs.bench,                       # telemetry: eff-TOPS, drift
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write rows as a BENCH_*.json record")
    ap.add_argument("--check", action="store_true",
                    help="CI smoke mode: tiny shapes, assert every suite "
                         "emits _total and no ERROR rows (exit 1 on "
                         "failure)")
    args = ap.parse_args()

    if args.check:
        # suites consult benchmarks._check.check_mode(); set before import
        import os
        os.environ["SOSA_BENCH_CHECK"] = "1"

    suites = load_suites()
    only = set(args.only.split(",")) if args.only else None
    selected = [n for n in suites if not only or n in only]
    rows: list[dict] = []
    print("name,us_per_call,derived")
    for name in selected:
        fn = suites[name]
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
                rows.append(parse_row(line))
        except Exception as e:  # noqa: BLE001
            err = error_row(name, e)
            print(err, flush=True)
            rows.append(parse_row(err))
        total = f"{name}/_total,{(time.time() - t0) * 1e6:.0f},done"
        print(total, flush=True)
        rows.append(parse_row(total))
    if args.json:
        write_json(rows, args.json)
    if args.check:
        problems = check_rows(rows, selected)
        for p in problems:
            print(f"CHECK FAIL: {p}", file=sys.stderr)
        print(f"--check: {len(selected)} suites, "
              f"{'FAIL' if problems else 'OK'}", file=sys.stderr)
        if problems:
            sys.exit(1)


if __name__ == "__main__":
    main()
