"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only granularity,...]

The ``dse`` suite emits a ``dse/engine_speedup`` row comparing the batched
analytical engine (core.dse.sweep -> simulator.analyze_batch) against the
original scalar loop (core.dse.sweep_scalar) on the Fig-5 mixed grid.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from benchmarks import (dse_map, granularity, interconnect, kernels_bench,
                            memory_sweep, multitenancy, scaling, tenancy,
                            tiling_sweep)
    suites = {
        "granularity": granularity.bench,       # Table 2 + Fig 9
        "interconnect": interconnect.bench,     # Table 1 + Fig 12a
        "tiling": tiling_sweep.bench,           # Fig 12b
        "dse": dse_map.bench,                   # Fig 5
        "multitenancy": multitenancy.bench,     # Fig 11
        "tenancy": tenancy.bench,               # tenant-mix DSE (repro.tenancy)
        "memory": memory_sweep.bench,           # Fig 13
        "scaling": scaling.bench,               # Fig 10
        "kernels": kernels_bench.bench,         # §4.1 pod microarchitecture
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
        print(f"{name}/_total,{(time.time() - t0) * 1e6:.0f},done",
              flush=True)


if __name__ == "__main__":
    main()
