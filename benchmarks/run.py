"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only granularity,...]
                                            [--json BENCH_foo.json]

The ``dse`` suite emits a ``dse/engine_speedup`` row comparing the batched
analytical engine (core.dse.sweep -> simulator.analyze_batch) against the
original scalar loop (core.dse.sweep_scalar) on the Fig-5 mixed grid; the
``serving`` suite compares the bucketed + fused ServeEngine hot loop
against the seed per-token engine (compile counts, tokens/s, p50/p99).

``--json`` additionally writes the rows as a machine-readable
``BENCH_*.json`` (schema ``sosa-bench-v1``) so the perf trajectory is
recorded across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def parse_row(line: str) -> dict:
    """One CSV row -> record. `derived` may itself contain ';'-separated
    key=value pairs; it is kept verbatim (strings stay greppable) and the
    row is split on the first two commas only."""
    name, us, derived = line.split(",", 2)
    suite = name.split("/", 1)[0]
    try:
        us_val = float(us)
    except ValueError:
        us_val = 0.0
    return {"suite": suite, "name": name, "us_per_call": us_val,
            "derived": derived}


def write_json(rows: list[dict], path: str) -> None:
    """BENCH_*.json schema: header + the parsed rows."""
    doc = {
        "schema": "sosa-bench-v1",
        "created_unix": time.time(),
        "argv": sys.argv[1:],
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write rows as a BENCH_*.json record")
    args = ap.parse_args()

    from benchmarks import (dse_map, granularity, interconnect, kernels_bench,
                            memory_sweep, multitenancy, scaling, serving,
                            tenancy, tiling_sweep)
    suites = {
        "granularity": granularity.bench,       # Table 2 + Fig 9
        "interconnect": interconnect.bench,     # Table 1 + Fig 12a
        "tiling": tiling_sweep.bench,           # Fig 12b
        "dse": dse_map.bench,                   # Fig 5
        "multitenancy": multitenancy.bench,     # Fig 11
        "tenancy": tenancy.bench,               # tenant-mix DSE (repro.tenancy)
        "memory": memory_sweep.bench,           # Fig 13
        "scaling": scaling.bench,               # Fig 10
        "kernels": kernels_bench.bench,         # §4.1 pod microarchitecture
        "serving": serving.bench,               # hot-loop engine vs seed
    }
    only = set(args.only.split(",")) if args.only else None
    rows: list[dict] = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
                rows.append(parse_row(line))
        except Exception as e:  # noqa: BLE001
            err = f"{name}/ERROR,0,{type(e).__name__}:{e}"
            print(err, flush=True)
            rows.append(parse_row(err))
        total = f"{name}/_total,{(time.time() - t0) * 1e6:.0f},done"
        print(total, flush=True)
        rows.append(parse_row(total))
    if args.json:
        write_json(rows, args.json)


if __name__ == "__main__":
    main()
