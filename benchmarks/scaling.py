"""Paper Fig 10 + §6 conclusion: strong scaling with pod count / TDP.

The paper reports up to ~600 TeraOps/s effective at 400 W for
compute-intensive CNNs (ResNet) when scaling pods, while batch-1 BERT
saturates early — reproduced with the analytical model.
"""

from __future__ import annotations

import time

from repro.core import ArrayConfig, AcceleratorConfig, analyze
from repro.core.dse import build_accel
from repro.core.workloads import bert, resnet


def bench() -> list[str]:
    lines = []
    t0 = time.time()
    for pods in (32, 64, 128, 256, 512):
        accel = build_accel(32, 32, num_pods=pods)
        rn = analyze(resnet(152, 299), accel)
        bt = analyze(bert("base", 100), accel)
        us = (time.time() - t0) * 1e6
        # Fig 10 style: effective throughput at the design's own peak power
        eff_r = rn.utilization * accel.peak_ops / 1e12
        eff_b = bt.utilization * accel.peak_ops / 1e12
        lines.append(f"scaling/pods{pods},{us:.0f},"
                     f"tdp={accel.peak_watts:.0f}W;"
                     f"resnet_eff={eff_r:.1f};bert_eff={eff_b:.1f};"
                     f"resnet_eff@400W={rn.effective_tops_at_tdp:.1f}")
    return lines
