"""Serving hot-loop benchmarks: the bucketed + fused ServeEngine vs the
seed per-token engine (serve/reference.py, the scalar oracle).

Three phases, each reported as `serving/...` rows:

  * prefill — a mixed-length prompt workload; the headline derived fields
    are the jit compile counts (reference: one per distinct prompt length;
    bucketed: one per power-of-two bucket) and their ratio (the >=5x
    acceptance gate).
  * decode — steady-state decode tokens/s for both engines plus p50/p99
    per-token latency. Timing is warm + min-of-2 (wall clock on this box
    is ~2x noisy): one warm pass compiles every chunk variant, then the
    best of two measured passes is reported. The fused multi-token loop's
    tokens/s over the reference's is the >=2x acceptance gate.
  * families — the decode-gap rows: an MoE arch (exact-length prefill,
    grouped-dispatch decode) and an SSM arch (now on the pow2 bucket
    path via masked state updates) through the same mixed workload,
    reporting tokens/s + prefill compile counts against the bounded-
    bucket guarantee.
  * autotune — the DSE block geometry choose_blocks picks for the
    full-scale fused decode GEMM shapes (pure model, no timing), incl.
    the transposed-weight LM-head and grouped MoE expert shapes.
  * admission — overload & failure semantics (serve/admission.py,
    serve/chaos.py): a fifo-overhead row (the admission-threaded engine
    on the steady-state decode workload — must hold the PR 7 decode
    rate), per-policy shed/goodput/SLO-attainment rows under a
    deterministic 2x-overload workload driven in *virtual time*
    (VirtualClock + seeded per-call service times, so the counts are
    exact and box-independent), and a seeded chaos row (transient faults
    + slow chunks: retries, sheds, slot-leak check).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from ._check import pick


def _mk_engine_parts(arch="granite-8b", seed=0):
    from repro.configs import get_arch, reduced
    from repro.models.model import Model
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _prompts(cfg, lengths, rng):
    return [rng.integers(0, cfg.vocab, int(n), dtype=np.int32)
            for n in lengths]


def _reset_requests(cfg, lengths, rng, max_new):
    from repro.serve.engine import Request
    return [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(_prompts(cfg, lengths, rng))]


def _prefill_phase(lines):
    """Mixed-length workload: 24 distinct prompt lengths -> 3 buckets."""
    from repro.serve.engine import ServeEngine
    from repro.serve.reference import ReferenceEngine
    cfg, model, params = _mk_engine_parts()
    lengths = pick(list(range(9, 57, 2)), [9, 17])   # 24 distinct, buckets
    max_len = 64                                     # {16, 32, 64}
    rng = np.random.default_rng(0)

    ref = ReferenceEngine(model, params, slots=4, max_len=max_len,
                          jit_prefill=True)
    new = ServeEngine(model, params, slots=4, max_len=max_len)

    def run(engine, seed):
        reqs = _reset_requests(cfg, lengths, np.random.default_rng(seed), 2)
        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        engine.run_to_completion(max_steps=500)
        assert all(r.done for r in reqs)
        return time.perf_counter() - t0

    # cold pass populates the jit caches (and the compile counts we gate
    # on); warm + min-of-2 for the steady-state wall clock
    for eng, name in ((ref, "ref"), (new, "bucketed")):
        run(eng, 0)
        dt = min(run(eng, 1), run(eng, 2))
        total_tokens = sum(lengths)
        if name == "ref":
            compiles = ref._prefill._cache_size()
            ref_compiles = compiles
            ref_dt = dt
        else:
            compiles = eng.prefill_compiles
            reduction = ref_compiles / max(1, compiles)
            lines.append(
                f"serving/prefill_mixed_{len(lengths)}lens,"
                f"{dt * 1e6:.0f},"
                f"ref_compiles={ref_compiles};bucketed_compiles={compiles};"
                f"compile_reduction={reduction:.1f}x;"
                f"warm_tok_s={total_tokens / dt:.0f};"
                f"ref_warm_tok_s={total_tokens / ref_dt:.0f}")
    return lines


def _decode_phase(lines):
    """Steady-state decode throughput: 4 lanes x 32 tokens, same bucket."""
    from repro.serve.engine import ServeEngine
    from repro.serve.reference import ReferenceEngine
    cfg, model, params = _mk_engine_parts()
    max_new = pick(33, 5)                            # 32 decode steps
    lengths = [8, 8, 8, 8]

    def decode_run(engine):
        """Prefill all lanes, then time the decode loop only; returns
        (seconds, per-token latencies). Latency is the honest next-token
        wait: every token delivered at a host sync is charged the full
        wall time of that step/chunk — this is what a consumer waits, and
        it makes the chunked engine's batched-delivery tail visible
        instead of smearing a chunk's time across its tokens."""
        reqs = _reset_requests(cfg, lengths, np.random.default_rng(0),
                               max_new)
        for r in reqs:
            engine.submit(r)
        engine._admit()
        lat: list[float] = []
        t0 = time.perf_counter()
        while any(engine.active):
            before = sum(len(r.out) for r in reqs)
            s0 = time.perf_counter()
            engine.step()
            ds = time.perf_counter() - s0
            got = sum(len(r.out) for r in reqs) - before
            if got:
                lat.extend([ds] * got)
        dt = time.perf_counter() - t0
        assert all(r.done and len(r.out) == max_new for r in reqs)
        return dt, lat

    results = {}
    for name, engine in (
            ("ref", ReferenceEngine(model, params, slots=4, max_len=64)),
            ("fused", ServeEngine(model, params, slots=4, max_len=64,
                                  decode_chunk=16))):
        decode_run(engine)                           # warm (compile)
        (d1, l1), (d2, l2) = decode_run(engine), decode_run(engine)
        dt, lat = min((d1, l1), (d2, l2), key=lambda t: t[0])
        toks = 4 * (max_new - 1)
        results[name] = toks / dt
        lines.append(
            f"serving/decode_{name},{dt / toks * 1e6:.0f},"
            f"tok_s={toks / dt:.0f};"
            f"p50_us={np.percentile(lat, 50) * 1e6:.0f};"
            f"p99_us={np.percentile(lat, 99) * 1e6:.0f}")
    lines.append(
        f"serving/decode_speedup,0,"
        f"fused_over_ref={results['fused'] / results['ref']:.2f}x")
    return lines


def _family_phase(lines):
    """MoE + SSM serving rows: the decode-gap families on the hot loop.

    dbrx (moe): exact-length prefill (capacity displacement keeps it off
    the bucket path — compile count equals #distinct lengths, the cost
    the bucketed gate exists to expose). Decode runs the sort (scatter)
    dispatch — the same capacity-bucketed assignment the grouped pod
    GEMM consumes under use_pallas — because interpret-mode Pallas is
    not timeable on CPU; the grouped-kernel hot path itself is gated by
    tests (parity matrix + grouped-gemm trace counts), not timed here.
    mamba2 (ssm): bucketed prefill via masked state updates — compile
    count must sit under the <= log2(max_len) bound.
    Timing is warm + min-of-2 on the jnp backend."""
    import dataclasses
    from repro.serve.engine import ServeEngine
    lengths = pick(list(range(5, 53, 4)), [5, 9])    # 12 distinct lengths
    max_new = pick(9, 3)
    for arch, tag in (("dbrx-132b", "moe"), ("mamba2-370m", "ssm")):
        cfg, model, params = _mk_engine_parts(arch)
        if cfg.moe is not None:
            from repro.models.model import Model
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, dispatch="sort"))
            model = Model(cfg)      # params are schema-identical across
            #                         dispatch modes — reuse them
        eng = ServeEngine(model, params, slots=4, max_len=64)

        def run(seed):
            reqs = _reset_requests(cfg, lengths, np.random.default_rng(seed),
                                   max_new)
            for r in reqs:
                eng.submit(r)
            t0 = time.perf_counter()
            eng.run_to_completion(max_steps=500)
            assert all(r.done for r in reqs)
            return time.perf_counter() - t0

        run(0)                                       # warm (compile)
        dt = min(run(1), run(2))
        toks = len(lengths) * max_new
        lines.append(
            f"serving/{tag}_mixed_{len(lengths)}lens,"
            f"{dt / toks * 1e6:.0f},"
            f"tok_s={toks / dt:.0f};bucketed={int(eng.bucketed)};"
            f"prefill_compiles={eng.prefill_compiles};"
            f"bucket_bound={eng.max_prefill_compiles}")
    return lines


def _autotune_phase(lines):
    """DSE-chosen pod geometry for full-scale serving GEMM shapes."""
    from repro.configs import get_arch
    from repro.parallel.autoshard import choose_blocks, choose_blocks_grouped
    cfg = get_arch("granite-8b")
    shapes = {
        "decode_qkv": (64, cfg.d_model, cfg.d_model),   # 64 fused lanes
        "decode_ffn": (64, cfg.d_model, cfg.d_ff),
        "prefill_ffn": (4096, cfg.d_model, cfg.d_ff),
        # transposed-weight LM head: 64 fused lanes against the stored
        # [vocab, d] table (layout-invariant cost model)
        "decode_lm_head": (64, cfg.d_model, cfg.vocab),
    }
    for name, (m, k, n) in shapes.items():
        bm, bn, bk = choose_blocks(m, k, n)
        lines.append(f"serving/autotune_{name},0,"
                     f"m={m};k={k};n={n};blocks={bm}x{bn}x{bk}")
    moe = get_arch("dbrx-132b")
    cap = 128                                        # per-expert bucket rows
    bm, bn, bk = choose_blocks_grouped(
        moe.moe.num_experts, cap, moe.d_model, moe.moe.d_ff_expert)
    lines.append(f"serving/autotune_moe_expert_ffn,0,"
                 f"g={moe.moe.num_experts};m={cap};k={moe.d_model};"
                 f"n={moe.moe.d_ff_expert};blocks={bm}x{bn}x{bk}")
    return lines


def _admission_phase(lines):
    """Overload & failure semantics rows (serve/admission.py, chaos.py).

    The overload rows run in VIRTUAL time: a VirtualClock the injector
    advances by a fixed service_seconds per device call. Deadline expiry,
    predictive shedding, and budget degradation then depend only on the
    (seeded) workload — the done/expired/rejected counts and attainment
    are exact integers on any box. The fifo-overhead row is real wall
    clock (warm + min-of-2), pinning the admission-threaded default
    engine to the PR 7 steady-state decode rate."""
    from repro.serve.admission import AdmissionConfig
    from repro.serve.chaos import ChaosConfig, VirtualClock
    from repro.serve.engine import Request, ServeEngine
    cfg, model, params = _mk_engine_parts()

    # fifo overhead: steady-state decode, default (seed-equivalent) engine
    max_new = pick(33, 5)
    lengths = [8, 8, 8, 8]

    def decode_run():
        eng = ServeEngine(model, params, slots=4, max_len=64,
                          decode_chunk=16)
        reqs = _reset_requests(cfg, lengths, np.random.default_rng(0),
                               max_new)
        for r in reqs:
            eng.submit(r)
        eng._admit()
        t0 = time.perf_counter()
        while any(eng.active):
            eng.step()
        dt = time.perf_counter() - t0
        assert all(r.done and r.state == "done" for r in reqs)
        return dt

    decode_run()                                     # warm (compile)
    dt = min(decode_run(), decode_run())
    toks = 4 * (max_new - 1)
    lines.append(f"serving/admission_fifo_overhead,{dt / toks * 1e6:.0f},"
                 f"tok_s={toks / dt:.0f};policy=fifo")

    # deterministic 2x-overload policy comparison (virtual time)
    n_req = pick(16, 6)
    over_new = pick(8, 3)
    service = 0.05

    def mk_reqs(tight, loose):
        rng = np.random.default_rng(11)
        reqs = []
        for i in range(n_req):
            p = rng.integers(0, cfg.vocab, int(rng.integers(5, 9)),
                             dtype=np.int32)
            reqs.append(Request(rid=i, prompt=p, max_new_tokens=over_new,
                                deadline_s=tight if i % 2 else loose))
        return reqs

    def overload_run(policy, tight=None, loose=None):
        clk = VirtualClock()
        eng = ServeEngine(
            model, params, slots=2, max_len=64, decode_chunk=8, clock=clk,
            admission=AdmissionConfig(policy=policy),
            chaos=ChaosConfig(seed=0, service_seconds=service))
        reqs = mk_reqs(tight, loose)
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion(max_steps=2000)
        if any(eng.active):
            raise RuntimeError(f"slot leak under {policy}")
        done_toks = sum(len(r.out) for r in reqs if r.state == "done")
        return eng, reqs, clk.t, done_toks

    # calibrate the deadline scale: total virtual time with no deadlines
    _, _, t_full, _ = overload_run("fifo")
    tight, loose = 0.35 * t_full, 3.0 * t_full
    att = {}
    for policy in ("fifo", "edf", "slo-aware"):
        eng, reqs, t, done_toks = overload_run(policy, tight, loose)
        c = eng.admission.counts
        att[policy] = eng.admission.slo_attainment
        lines.append(
            f"serving/admission_overload_{policy.replace('-', '_')},0,"
            f"slo_attainment={att[policy]:.3f};done={c['done']};"
            f"expired={c['expired']};rejected={c['rejected']};"
            f"degraded={c['degraded']};goodput_tok_per_vs={done_toks / t:.1f};"
            f"virtual_s={t:.2f};offered={n_req}")
    if att["edf"] <= att["fifo"] or att["slo-aware"] <= att["fifo"]:
        raise RuntimeError(
            f"deadline policies must beat fifo attainment under overload: "
            f"{att}")
    lines.append(
        f"serving/admission_policy_gain,0,"
        f"edf_minus_fifo={att['edf'] - att['fifo']:.3f};"
        f"slo_aware_minus_fifo={att['slo-aware'] - att['fifo']:.3f}")

    # seeded chaos: transient faults + slow chunks through the retry path
    clk = VirtualClock()
    eng = ServeEngine(
        model, params, slots=2, max_len=64, clock=clk,
        admission=AdmissionConfig(policy="edf"),
        chaos=ChaosConfig(seed=3, p_fault=0.3, p_slow=0.3,
                          service_seconds=0.01, transient_tries=1))
    reqs = mk_reqs(None, None)[: pick(8, 4)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=2000)
    leaks = sum(1 for r in eng.active if r is not None)
    if leaks or any(not r.finished for r in reqs):
        raise RuntimeError(f"chaos run leaked slots ({leaks}) or left "
                           f"non-terminal requests")
    c = eng.admission.counts
    inj = eng._chaos.injected
    lines.append(
        f"serving/admission_chaos,0,"
        f"injected_faults={inj['faults']};injected_slow={inj['slow']};"
        f"device_calls={inj['calls']};done={c['done']};"
        f"rejected={c['rejected']};expired={c['expired']};slot_leaks=0")
    return lines


def _sdc_phase(lines):
    """Silent-data-corruption rows (PodGuard + kernel-level chaos SDC).

    sdc_chaos runs the pallas pod-GEMM engine under the abft guard with a
    seeded SDC schedule in virtual time: the corrected / uncorrectable /
    retry counts are exact integers on any box, and the run must finish
    with zero slot leaks. sdc_guard_overhead times steady-state decode
    with the guard off vs abft on the SAME pallas model (warm +
    min-of-2), reporting the checksum envelope's throughput cost —
    the paper-level claim is <=10% on real pod hardware; here the row
    records the measured ratio on the interpret-mode backend."""
    from repro.models.model import Model
    from repro.serve.chaos import ChaosConfig, VirtualClock
    from repro.serve.engine import ServeEngine
    cfg, model, params = _mk_engine_parts()
    pallas_model = Model(cfg, use_pallas=True)

    # seeded SDC chaos through the guard + retry path (virtual time)
    max_new = pick(6, 3)
    eng = ServeEngine(pallas_model, params, slots=4, max_len=64,
                      guard="abft", clock=VirtualClock(), max_retries=3,
                      chaos=ChaosConfig(seed=7, p_sdc=0.5, sdc_elems=1,
                                        service_seconds=0.01,
                                        transient_tries=1))
    reqs = _reset_requests(cfg, [5, 7, 9, 11], np.random.default_rng(2),
                           max_new)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=2000)
    leaks = sum(1 for r in eng.active if r is not None)
    if leaks or any(not r.finished for r in reqs):
        raise RuntimeError(f"sdc chaos run leaked slots ({leaks}) or left "
                           f"non-terminal requests")
    ge, inj = eng.guard_events, eng._chaos.injected
    if inj["sdc"] and not (ge["corrected"] or ge["uncorrectable"]):
        raise RuntimeError("injected SDC was never seen by the guard")
    c = eng.admission.counts
    lines.append(
        f"serving/sdc_chaos,0,"
        f"injected_sdc={inj['sdc']};corrected={ge['corrected']};"
        f"uncorrectable={ge['uncorrectable']};device_calls={inj['calls']};"
        f"done={c['done']};rejected={c['rejected']};slot_leaks=0")

    # guard overhead: steady-state decode off vs abft on the pallas model
    max_new2 = pick(17, 3)
    lengths = [8, 8, 8, 8]

    def decode_run(engine):
        reqs = _reset_requests(cfg, lengths, np.random.default_rng(0),
                               max_new2)
        for r in reqs:
            engine.submit(r)
        engine._admit()
        t0 = time.perf_counter()
        while any(engine.active):
            engine.step()
        dt = time.perf_counter() - t0
        assert all(r.done and r.state == "done" for r in reqs)
        return dt

    rates = {}
    for guard in ("off", "abft"):
        engine = ServeEngine(pallas_model, params, slots=4, max_len=64,
                             decode_chunk=8, guard=guard)
        decode_run(engine)                           # warm (compile)
        dt = min(decode_run(engine), decode_run(engine))
        toks = 4 * (max_new2 - 1)
        rates[guard] = toks / dt
    ratio = rates["off"] / rates["abft"]

    # The hardware-relevant steady-state number: the wave model's cycles
    # for the full-scale 64-lane decode GEMM stream, off vs abft at the
    # deployment design point. The checksum ROW rides the array's tile
    # slack — one of the 64 fused lanes is reserved for it (63 data lanes
    # + checksum row fill the same 32-row tiles), because a naive 65th
    # row would round up to a whole extra tile pass under the tile-
    # quantized wave model. abft's cost is then the lost lane plus the
    # +1 checksum column; exact and box-independent, so the <=10% budget
    # is asserted. The wall ratio above is an interpret-mode emulation
    # artifact: at the reduced 4-lane shapes the +1 row crosses a pow2
    # block boundary and doubles the pallas grid.
    from repro.configs import get_arch
    from repro.core import analyze
    from repro.core.dse import build_accel
    from repro.core.tiling import GemmSpec
    full = get_arch("granite-8b")
    lanes = 64
    shapes = [("qkv", full.d_model, full.d_model),
              ("ffn", full.d_model, full.d_ff),
              ("lm_head", full.d_model, full.vocab)]
    accel = build_accel(32, 32, num_pods=256)

    def stream_cycles(n_extra, faulty=0):
        gemms = [GemmSpec(lanes, k, n + n_extra, gemm_id=i, name=nm)
                 for i, (nm, k, n) in enumerate(shapes)]
        return analyze(gemms, accel, faulty_pods=faulty).total_cycles

    # tokens/cycle: off = lanes/cycles(N); abft = (lanes-1)/cycles(N+1)
    modeled = 1.0 - ((lanes - 1) / lanes) * (stream_cycles(0)
                                             / stream_cycles(1))
    if modeled > 0.10:
        raise RuntimeError(
            f"modeled abft decode overhead {modeled:.1%} exceeds the 10% "
            f"budget at the full-scale design point")
    lines.append(
        f"serving/sdc_guard_overhead,0,"
        f"modeled_decode_overhead={modeled * 100:.1f}%;"
        f"modeled_lanes={lanes - 1}+1checksum;"
        f"off_tok_s={rates['off']:.0f};abft_tok_s={rates['abft']:.0f};"
        f"interpret_wall_ratio={ratio:.2f}x")

    # degraded-pod throughput: the same decode stream with pods masked
    # out of the 256-pod machine (faulty pods' tiles remap onto
    # survivors) — predicted capacity must shed monotonically
    masked = (16, 64, 128)
    degr = {f: stream_cycles(0, faulty=f) for f in (0,) + masked}
    if any(degr[a] > degr[b] for a, b in zip((0,) + masked, masked)):
        raise RuntimeError(f"degraded-pod cycles must be monotone in "
                           f"masked pods: {degr}")
    lines.append(
        f"serving/sdc_degraded_pods,0,pods={accel.num_pods};" +
        ";".join(f"tput_frac_f{f}={degr[0] / degr[f]:.3f}"
                 for f in masked))
    return lines


def _paged_phase(lines):
    """Paged-KV rows (serve/paging.py + ServeEngine(paged=True)).

    paged_kv_bytes_* — device KV footprint vs the dense slots x max_len
    reservation at a low-occupancy and a near-full workload. The mapped
    bytes must hold the acceptance bound (<= 1.25x live tokens x
    per-token bytes, page-granularity slack) at every sampled quantum —
    violated bounds raise, so `--check` gates them.
    paged_decode — steady-state decode tokens/s, paged vs dense engine on
    the same workload (warm + min-of-2). The gather indirection rides the
    fused chunk, so the paged rate must stay within 2x of dense wall
    clock even on this interpret-mode box.
    paged_recycle — deterministic virtual-time overload with more
    requests than lanes: counts in-chunk lane handoffs and asserts the
    engine never runs an idle chunk while work is pending (the recycle
    latency claim: a freed lane is re-armed at the SAME chunk sync).
    """
    from repro.serve.chaos import ChaosConfig, VirtualClock
    from repro.serve.engine import ServeEngine
    cfg, model, params = _mk_engine_parts()

    # -- KV footprint at low / high occupancy --------------------------
    for tag, max_len, plens, max_new in (
            ("low_occupancy", 128, [16, 18, 20, 22], pick(5, 3)),
            ("full_occupancy", 64, [49, 52, 47, 50], pick(13, 5))):
        eng = ServeEngine(model, params, slots=4, max_len=max_len,
                          decode_chunk=4, paged=True, page_size=8)
        reqs = _reset_requests(cfg, plens, np.random.default_rng(3),
                               max_new)
        for r in reqs:
            eng.submit(r)
        eng._admit()                     # sample the post-prefill state
        peak = None
        for _ in range(500):
            s = eng.paged_kv_stats()
            if not s["live_tokens"]:
                if not eng.queue and not any(eng.active):
                    break
                eng.step()
                continue
            if s["mapped_bytes"] > 1.25 * s["live_tokens"] \
                    * s["kv_bytes_per_token"]:
                raise RuntimeError(f"paged KV bound violated ({tag}): {s}")
            if s["mapped_bytes"] > s["dense_bytes"]:
                raise RuntimeError(f"paged KV exceeds dense ({tag}): {s}")
            if peak is None or s["mapped_bytes"] > peak["mapped_bytes"]:
                peak = s
            if not eng.queue and not any(eng.active):
                break
            eng.step()
        if not all(r.state == "done" for r in reqs):
            raise RuntimeError(f"paged run left unfinished requests ({tag})")
        eng._pool.assert_drained()
        lines.append(
            f"serving/paged_kv_bytes_{tag},0,"
            f"mapped_kib={peak['mapped_bytes'] / 1024:.0f};"
            f"dense_kib={peak['dense_bytes'] / 1024:.0f};"
            f"dense_frac={peak['mapped_bytes'] / peak['dense_bytes']:.2f};"
            f"occupancy={peak['occupancy']:.2f};"
            f"live_tokens={peak['live_tokens']};"
            f"mapped_tokens={peak['mapped_tokens']};"
            f"kv_bytes_per_token={peak['kv_bytes_per_token']}")

    # -- steady-state decode: paged vs dense ---------------------------
    max_new = pick(33, 5)
    lengths = [8, 8, 8, 8]

    def decode_run(engine):
        reqs = _reset_requests(cfg, lengths, np.random.default_rng(0),
                               max_new)
        for r in reqs:
            engine.submit(r)
        engine._admit()
        t0 = time.perf_counter()
        while any(engine.active):
            engine.step()
        dt = time.perf_counter() - t0
        assert all(r.done and len(r.out) == max_new for r in reqs)
        return dt

    rates = {}
    for name, kw in (("dense", {}), ("paged", dict(paged=True,
                                                   page_size=8))):
        engine = ServeEngine(model, params, slots=4, max_len=64,
                             decode_chunk=16, **kw)
        decode_run(engine)                           # warm (compile)
        dt = min(decode_run(engine), decode_run(engine))
        toks = 4 * (max_new - 1)
        rates[name] = toks / dt
    ratio = rates["paged"] / rates["dense"]
    if ratio < 0.5:
        raise RuntimeError(
            f"paged decode fell to {ratio:.2f}x of dense — the page "
            f"gather must ride the fused chunk, not re-materialize it")
    lines.append(
        f"serving/paged_decode,0,"
        f"paged_tok_s={rates['paged']:.0f};dense_tok_s={rates['dense']:.0f};"
        f"paged_over_dense={ratio:.2f}x")

    # -- in-chunk lane recycling (deterministic, virtual time) ---------
    eng = ServeEngine(model, params, slots=2, max_len=32, decode_chunk=4,
                      clock=VirtualClock(), paged=True, page_size=8,
                      chaos=ChaosConfig(seed=0, service_seconds=0.01))
    n_req = pick(8, 5)
    reqs = _reset_requests(cfg, [6] * n_req, np.random.default_rng(1),
                           pick(6, 4))
    for r in reqs:
        eng.submit(r)
    idle_chunks = 0
    chunks = 0
    for _ in range(2000):
        if not eng.queue and not any(eng.active):
            break
        live = eng.step()
        chunks += 1
        if live == 0:
            idle_chunks += 1
    if not all(r.state == "done" for r in reqs):
        raise RuntimeError("recycle run left unfinished requests")
    if idle_chunks:
        raise RuntimeError(
            f"{idle_chunks} idle chunks with work pending: mid-chunk "
            f"retires must hand lanes over at the same sync")
    if eng.recycled < n_req - eng.slots:
        raise RuntimeError(
            f"expected >= {n_req - eng.slots} in-chunk recycles, got "
            f"{eng.recycled}")
    eng._pool.assert_drained()
    lines.append(
        f"serving/paged_recycle,0,"
        f"offered={n_req};slots={eng.slots};recycled={eng.recycled};"
        f"chunks={chunks};idle_chunks=0;"
        f"recycle_rate={eng.recycled / chunks:.2f}")
    return lines


def bench() -> list[str]:
    lines: list[str] = []
    _prefill_phase(lines)
    _decode_phase(lines)
    _family_phase(lines)
    _autotune_phase(lines)
    _admission_phase(lines)
    _sdc_phase(lines)
    _paged_phase(lines)
    return lines
