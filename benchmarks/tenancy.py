"""Tenant-mix DSE on the batched co-schedule planner (repro.tenancy).

The multi-tenant counterpart of the Fig-5 granularity sweep: for every
pair-mix over a 5-workload suite, find the pod granularity that maximizes
co-scheduled effective TOPS @TDP — the whole (8 designs x 10 mixes) grid
is ONE analyze_batch call (tenancy.sweep.mix_dse). A second phase compares
the time-multiplexed and space-shared policies on the Fig-11 mix
(tenancy.planner), reporting per-policy fairness and SLO-free latency.
"""

from __future__ import annotations

import time

from repro.tenancy import (SPACE_SHARE, TIME_MUX, default_mixes, dse_designs,
                           fig11_mixes, mix_dse, plan_mixes)


def bench() -> list[str]:
    lines = []

    # phase 1 — best granularity per mix, one batched planner call
    mixes = default_mixes()
    designs = dse_designs()
    t0 = time.time()
    best = mix_dse(mixes, designs)
    us = (time.time() - t0) * 1e6 / max(1, len(best))
    for name, plan in sorted(best.items()):
        lines.append(
            f"tenancy/mixdse/{name},{us:.0f},"
            f"best={plan.rows}x{plan.cols}x{plan.num_pods};"
            f"eff_tops={plan.effective_tops_at_tdp:.1f};"
            f"gain={plan.parallel_gain:.2f}x;"
            f"fairness={plan.fairness:.3f}")

    # phase 2 — policy face-off on the Fig-11 mix (paper's §6.1 cell)
    f11 = fig11_mixes(batches=(1,))
    cell = [(32, 32, "butterfly-2", 256)]
    for policy in (TIME_MUX, SPACE_SHARE):
        t0 = time.time()
        plan = plan_mixes(f11, cell, policy=policy)[0][0]
        us = (time.time() - t0) * 1e6
        worst = max(plan.streams, key=lambda s: s.slowdown)
        lines.append(
            f"tenancy/policy/{policy},{us:.0f},"
            f"eff_tops={plan.effective_tops_at_tdp:.1f};"
            f"gain={plan.parallel_gain:.2f}x;"
            f"fairness={plan.fairness:.3f};"
            f"worst_slowdown={worst.slowdown:.2f}x@{worst.tenant}")
    return lines
