"""Paper Fig 12b: effective throughput vs activation partition size k.

The paper's pillar 3: k = r (32) maximizes parallel tile ops without
exposing the weight-buffering time; k >> r starves pods, k < r stalls them.

The whole k sweep is one batched call: the same design replicated per k
candidate with a per-point `k_part` array (the batched engine broadcasts
k_part over the grid axis).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ArrayConfig, AcceleratorConfig, analyze, merge_workloads
from repro.core.simulator import DesignVector, analyze_batch, pack_workloads
from repro.core.workloads import bert, resnet

K_CANDIDATES = (8, 16, 32, 64, 128, 512, 10 ** 9)


def bench(pods: int = 256) -> list[str]:
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=pods)
    wl = merge_workloads(resnet(50, 299), bert("base", 100))
    lines = []

    # batched: one analyze_batch over all k candidates at once — the same
    # accelerator (Table-1 0.52 mW/B default) replicated per k, so every
    # row of this CSV shares one peak-power normalization
    t0 = time.time()
    packed = pack_workloads({"mixed": wl})
    dv = DesignVector.from_accel(accel, "butterfly-2").repeat(len(K_CANDIDATES))
    batch = analyze_batch(packed, dv,
                          k_part=np.array(K_CANDIDATES, dtype=np.int64))
    us = (time.time() - t0) * 1e6 / len(K_CANDIDATES)
    for i, k in enumerate(K_CANDIDATES):
        kname = "none" if k == 10 ** 9 else str(k)
        lines.append(f"tiling/k={kname},{us:.0f},"
                     f"eff_tops={batch.effective_tops_at_tdp[i, 0]:.1f};"
                     f"util={batch.utilization[i, 0]:.3f}")
    i_opt = K_CANDIDATES.index(32)
    i_none = K_CANDIDATES.index(10 ** 9)
    lines.append(f"tiling/gain_over_none,0,"
                 f"{batch.utilization[i_opt, 0] / max(1e-9, batch.utilization[i_none, 0]):.2f}x")

    # BERT-only at high pod counts shows the paper's up-to-5x claim
    bl = bert("medium", 100)
    rb_none = analyze(bl, accel, k_part=10 ** 9)
    rb_opt = analyze(bl, accel, k_part=32)
    lines.append(f"tiling/gain_bert_256pods,0,"
                 f"{rb_opt.utilization / max(1e-9, rb_none.utilization):.2f}x")
    return lines
