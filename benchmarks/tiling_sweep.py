"""Paper Fig 12b: effective throughput vs activation partition size k.

The paper's pillar 3: k = r (32) maximizes parallel tile ops without
exposing the weight-buffering time; k >> r starves pods, k < r stalls them.
"""

from __future__ import annotations

import time

from repro.core import ArrayConfig, AcceleratorConfig, analyze, merge_workloads
from repro.core.workloads import bert, resnet


def bench(pods: int = 256) -> list[str]:
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=pods)
    wl = merge_workloads(resnet(50, 299), bert("base", 100))
    lines = []
    base = None
    for k in (8, 16, 32, 64, 128, 512, 10 ** 9):
        t0 = time.time()
        r = analyze(wl, accel, k_part=k)
        us = (time.time() - t0) * 1e6
        if k == 32:
            base = r.effective_tops_at_tdp
        kname = "none" if k == 10 ** 9 else str(k)
        lines.append(f"tiling/k={kname},{us:.0f},"
                     f"eff_tops={r.effective_tops_at_tdp:.1f};"
                     f"util={r.utilization:.3f}")
    r_none = analyze(wl, accel, k_part=10 ** 9)
    r_opt = analyze(wl, accel, k_part=32)
    lines.append(f"tiling/gain_over_none,0,"
                 f"{r_opt.utilization / max(1e-9, r_none.utilization):.2f}x")
    # BERT-only at high pod counts shows the paper's up-to-5x claim
    bl = merge_workloads(*[bert("medium", 100) for _ in range(1)])
    rb_none = analyze(bl, accel, k_part=10 ** 9)
    rb_opt = analyze(bl, accel, k_part=32)
    lines.append(f"tiling/gain_bert_256pods,0,"
                 f"{rb_opt.utilization / max(1e-9, rb_none.utilization):.2f}x")
    return lines
