"""Example: reproduce the paper's design-space exploration (Fig 5 / Table 2)
and print an ASCII effective-throughput/W heatmap.

The sweep runs through the batched analytical engine (core.dse.sweep ->
simulator.analyze_batch): the whole (rows x cols x workload) grid is one
NumPy evaluation. Pass --scalar to use the original per-point loop and see
the wall-time difference.

    PYTHONPATH=src python examples/explore_design_space.py [--scalar]
"""

import sys
import time

from repro.core.dse import best_point, sweep, sweep_scalar, table2_rows
from repro.core.workloads import full_suite

suite = full_suite(batch=1)
use_scalar = "--scalar" in sys.argv[1:]

print("=== Table 2 (effective throughput @ 400 W) ===")
print(f"{'design':>10} {'pods':>5} {'peak':>6} {'util':>6} {'effective':>9}")
for p in table2_rows(suite):
    print(f"{p.rows:>4}x{p.cols:<5} {p.num_pods:>5} "
          f"{p.peak_tops_at_tdp:>6.0f} {p.utilization:>6.3f} "
          f"{p.effective_tops_at_tdp:>9.1f}")

rows = (8, 16, 32, 64, 128, 256)
cols = (8, 16, 32, 64, 128, 256)
t0 = time.time()
pts = (sweep_scalar if use_scalar else sweep)(suite, rows, cols)
dt = time.time() - t0
best = best_point(pts)
engine = "scalar loop" if use_scalar else "batched engine"
print(f"\n=== Fig 5c heatmap (mixed suite), best {best.rows}x{best.cols} "
      f"@ {best.effective_tops_at_tdp:.0f} TOPS "
      f"[{len(pts)} points in {dt * 1e3:.0f} ms, {engine}] ===")
grid = {(p.rows, p.cols): p.effective_tops_at_tdp for p in pts}
mx = max(grid.values())
shades = " .:-=+*#%@"
print("      " + "".join(f"{c:>6}" for c in cols) + "   (cols)")
for r in rows:
    cells = "".join(
        f"{shades[min(9, int(10 * grid[(r, c)] / mx))] * 5:>6}"
        for c in cols)
    print(f"{r:>5} {cells}")
print("(rows)   darker = higher effective TOPS/W")
