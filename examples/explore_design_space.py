"""Example: reproduce the paper's design-space exploration (Fig 5 / Table 2)
and print an ASCII effective-throughput/W heatmap.

    PYTHONPATH=src python examples/explore_design_space.py
"""

from repro.core.dse import best_point, evaluate_design, sweep, table2_rows
from repro.core.workloads import full_suite

suite = full_suite(batch=1)

print("=== Table 2 (effective throughput @ 400 W) ===")
print(f"{'design':>10} {'pods':>5} {'peak':>6} {'util':>6} {'effective':>9}")
for p in table2_rows(suite):
    print(f"{p.rows:>4}x{p.cols:<5} {p.num_pods:>5} "
          f"{p.peak_tops_at_tdp:>6.0f} {p.utilization:>6.3f} "
          f"{p.effective_tops_at_tdp:>9.1f}")

rows = (8, 16, 32, 64, 128, 256)
cols = (8, 16, 32, 64, 128, 256)
pts = sweep(suite, rows, cols)
best = best_point(pts)
print(f"\n=== Fig 5c heatmap (mixed suite), best {best.rows}x{best.cols} "
      f"@ {best.effective_tops_at_tdp:.0f} TOPS ===")
grid = {(p.rows, p.cols): p.effective_tops_at_tdp for p in pts}
mx = max(grid.values())
shades = " .:-=+*#%@"
print("      " + "".join(f"{c:>6}" for c in cols) + "   (cols)")
for r in rows:
    cells = "".join(
        f"{shades[min(9, int(10 * grid[(r, c)] / mx))] * 5:>6}"
        for c in cols)
    print(f"{r:>5} {cells}")
print("(rows)   darker = higher effective TOPS/W")
