"""Quickstart: the SOSA pipeline end to end on one GEMM.

    PYTHONPATH=src python examples/quickstart.py

1. Build the paper's accelerator (256 pods of 32x32, Butterfly-2, 400 W).
2. Tile a GEMM with the r x r partition, schedule it across pods under the
   bank + butterfly routing constraints, and *numerically execute* the
   schedule (int8 in, int32 psums) against numpy.
3. Report the paper's headline metric (effective TOPS @ 400 W) for the
   workload, and the same decision applied to a TPU Pallas kernel's blocks.
"""

import numpy as np

from repro.core import ArrayConfig, analyze, sosa
from repro.core.executor import run_gemm_on_sosa
from repro.core.workloads import bert
from repro.parallel.autoshard import choose_blocks

# 1. the paper's design point
accel = sosa(rows=32, cols=32)
print(f"SOSA: {accel.num_pods} pods of "
      f"{accel.array.rows}x{accel.array.cols}, "
      f"peak {accel.peak_ops / 1e12:.0f} TOPS @ {accel.peak_watts:.0f} W "
      f"({accel.peak_ops_at_tdp / 1e12:.0f} TOPS isopower@400W)")

# 2. tile + schedule + execute one GEMM
rng = np.random.default_rng(0)
x = rng.integers(-100, 100, (100, 768), dtype=np.int8)   # BERT-ish layer
w = rng.integers(-100, 100, (768, 768), dtype=np.int8)
out, sched, graph = run_gemm_on_sosa(x, w, ArrayConfig(32, 32), num_pods=64)
ref = x.astype(np.int32) @ w.astype(np.int32)
assert np.array_equal(out, ref), "schedule executed wrong math!"
print(f"GEMM 100x768x768 -> {len(graph)} tile ops over "
      f"{sched.num_slices} slices on 64 pods "
      f"(busy {100 * sched.pods_busy_fraction():.0f}%), numerics exact.")

# 3. the paper's metric on a real workload
res = analyze(bert("base", seq=100), accel)
print(f"BERT-base @ seq 100: utilization {100 * res.utilization:.1f}%, "
      f"effective {res.effective_tops_at_tdp:.0f} TOPS @ 400 W")

# the same granularity trade-off, applied to a TPU Pallas GEMM
bm, bn, bk = choose_blocks(4096, 4096, 11008)
print(f"TPU mapping: MXU-pod blocks for a 4096x4096x11008 GEMM -> "
      f"bm={bm} bn={bn} bk={bk}")
