"""Example: batched serving with continuous batching (the paper's kind —
SOSA is an inference accelerator; multi-tenant co-scheduling is its §6.1
argument, realized here as mixed-length requests sharing decode batches).

    PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys

p = subprocess.run([
    sys.executable, "-m", "repro.launch.serve", "--arch", "yi-6b",
    "--reduced", "--requests", "6", "--slots", "3", "--max-new", "10",
    "--max-len", "96"])
assert p.returncode == 0
print("batched serving example: OK")
