"""Example: batched serving with continuous batching (the paper's kind —
SOSA is an inference accelerator; multi-tenant co-scheduling is its §6.1
argument, realized here as mixed-length requests sharing decode batches),
with the full telemetry stack on: the metrics snapshot prints after the
run and the timeline lands as a Perfetto-loadable Chrome trace.

    PYTHONPATH=src python examples/serve_lm.py
"""

import json
import os
import subprocess
import sys
import tempfile

trace_path = os.path.join(tempfile.mkdtemp(prefix="sosa-serve-"),
                          "serve_trace.json")
p = subprocess.run([
    sys.executable, "-m", "repro.launch.serve", "--arch", "yi-6b",
    "--reduced", "--requests", "6", "--slots", "3", "--max-new", "10",
    "--max-len", "96", "--metrics", "--trace-out", trace_path])
assert p.returncode == 0
doc = json.load(open(trace_path))
spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
assert spans, "serving run exported no spans"
print(f"trace: {len(spans)} spans at {trace_path} "
      f"(drag into ui.perfetto.dev)")
print("batched serving example: OK")
