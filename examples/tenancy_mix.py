"""Multi-tenant co-scheduling walkthrough (repro.tenancy).

Three stops:
  1. the Fig-11 reproduction — ResNet + 2x BERT co-scheduled vs
     back-to-back sequential, across pod counts (one batched planner call);
  2. policy face-off — time-multiplexed vs space-shared pods, with
     per-tenant latency, SLO attainment and Jain fairness;
  3. the serve bridge — a recorded continuous-batching timeline
     (synthetic here; ServeEngine(tracer=...) records a real one) planned
     against a CNN tenant.

Run:  PYTHONPATH=src python examples/tenancy_mix.py
"""

from __future__ import annotations

from repro.configs import get_arch, reduced
from repro.core.workloads import bert, resnet
from repro.tenancy import (SPACE_SHARE, TIME_MUX, ServeTraceRecorder, Tenant,
                           TenantMix, fig11_mixes, plan_mixes, plan_time_mux,
                           trace_tenant)


def show(plan) -> None:
    print(f"  [{plan.policy:>11}] {plan.mix}: "
          f"eff={plan.effective_tops_at_tdp:6.1f} TOPS  "
          f"seq={plan.sequential_effective_tops:6.1f}  "
          f"gain={plan.parallel_gain:.2f}x  fair={plan.fairness:.3f}  "
          f"slo={plan.slo_attainment:.0%}")
    for s in plan.streams:
        tag = "" if s.slo_met is None else ("  SLO ok" if s.slo_met
                                            else "  SLO MISS")
        print(f"      {s.tenant:<18} {s.latency_s * 1e6:8.1f} us "
              f"(solo {s.solo_latency_s * 1e6:8.1f} us, "
              f"x{s.slowdown:.2f}, {s.pods} pods){tag}")


def main() -> None:
    print("== Fig 11: co-scheduling vs sequential (batch 1) ==")
    mixes = fig11_mixes(batches=(1,))
    for pods in (128, 256):
        plan = plan_time_mux(mixes, [(32, 32, "butterfly-2", pods)])[0][0]
        print(f"  {pods} pods: gain={plan.parallel_gain:.2f}x "
              f"(paper: 1.44x at 256)")

    print("\n== policy face-off on 256 pods ==")
    slo_mix = TenantMix(name="rn+bert", tenants=(
        Tenant(name="resnet50", gemms=tuple(resnet(50, 224)),
               slo_latency_s=120e-6),
        Tenant(name="bert-medium", gemms=tuple(bert("medium", 100)),
               replicas=2, slo_latency_s=80e-6)))
    for policy in (TIME_MUX, SPACE_SHARE):
        plan = plan_mixes([slo_mix], [(32, 32, "butterfly-2", 256)],
                          policy=policy)[0][0]
        show(plan)

    print("\n== serve-engine trace as a tenant ==")
    cfg = reduced(get_arch("granite-8b"))
    rec = ServeTraceRecorder()          # ServeEngine(tracer=rec) feeds this
    rec.on_prefill(0, 24)
    for step in range(8):
        rec.on_decode(2, [24 + step, 16 + step])
    lm = trace_tenant("lm-serve", rec, cfg)
    plan = plan_time_mux(
        [TenantMix(name="serve+cnn", tenants=(
            lm, Tenant(name="resnet50", gemms=tuple(resnet(50, 64)))))],
        [(32, 32, "butterfly-2", 64)])[0][0]
    show(plan)


if __name__ == "__main__":
    main()
