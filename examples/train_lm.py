"""Example: end-to-end training with checkpoint/restart (CPU-sized).

    PYTHONPATH=src python examples/train_lm.py

Trains a reduced llama-family model on the deterministic synthetic stream,
simulates a mid-run failure, then resumes from the newest committed
checkpoint — the fault-tolerance path a 1000-node run relies on
(train/checkpoint.py + train/fault.py). Thin wrapper over
repro.launch.train (the real driver).
"""

import shutil
import subprocess
import sys

CKPT = "/tmp/repro_example_ckpt"

shutil.rmtree(CKPT, ignore_errors=True)
base = [sys.executable, "-m", "repro.launch.train", "--arch", "granite-8b",
        "--reduced", "--steps", "30", "--batch", "8", "--seq", "64",
        "--ckpt-dir", CKPT, "--ckpt-every", "10"]

print("=== phase 1: train until a simulated failure at step 15 ===")
p = subprocess.run(base + ["--kill-at", "15"])
assert p.returncode == 42, "expected the simulated failure exit code"

print("=== phase 2: resume from the newest committed checkpoint ===")
p = subprocess.run(base + ["--resume"])
assert p.returncode == 0
print("resume-after-failure path: OK")
