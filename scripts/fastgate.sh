#!/usr/bin/env bash
# The documented fast gate (see pyproject.toml / ROADMAP.md), one command:
#
#   scripts/fastgate.sh            # not-slow tests + benchmark --check smoke
#   scripts/fastgate.sh --tier1    # quickest signal: tier1 marker only
#
# Exits nonzero if either the test subset or the benchmark smoke fails
# (benchmarks.run --check asserts every suite emits its _total row and no
# ERROR rows). The full tier-1 verify (slow parity matrix included) stays
#   PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."

marker="not slow"
if [[ "${1:-}" == "--tier1" ]]; then
    marker="tier1"
    shift
fi

PYTHONPATH=src python -m pytest -q -m "$marker" "$@"
PYTHONPATH=src python -m benchmarks.run --check
