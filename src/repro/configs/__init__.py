from .base import (ArchConfig, MLAConfig, MoEConfig, SSMConfig, ShapeConfig,
                   SHAPES, REGISTRY, applicable_shapes, get_arch, list_archs,
                   reduced)
from .all_archs import ALL_ARCHS  # registers every arch

__all__ = ["ArchConfig", "MLAConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
           "SHAPES", "REGISTRY", "ALL_ARCHS", "applicable_shapes", "get_arch",
           "list_archs", "reduced"]
