"""The 10 assigned architectures (+ the paper's own workloads live in
core/workloads.py). Exact dims from the assignment table; sources noted."""

from .base import (ArchConfig, MLAConfig, MoEConfig, SSMConfig, register)


@register("deepseek-v2-236b")
def deepseek_v2_236b() -> ArchConfig:
    # [arXiv:2405.04434; hf] 60L d_model=5120 128H MLA(kv_lora=512)
    # MoE: 2 shared + 160 routed top-6, expert d_ff=1536; first layer dense.
    return ArchConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288,  # dense-layer FFN (DeepSeek-V2 first layer)
        vocab=102400, head_dim=192,  # qk_nope 128 + rope 64
        activation="silu",
        moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                      num_shared_experts=2, first_dense_layers=1),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
    )


@register("dbrx-132b")
def dbrx_132b() -> ArchConfig:
    # [hf:databricks/dbrx-base; unverified] 40L d=6144 48H GQA kv=8
    # MoE 16 experts top-4, fine-grained, d_ff=10752.
    return ArchConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100352, activation="silu", norm="layernorm",
        rope_theta=500000.0,
        moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
    )


@register("whisper-small")
def whisper_small() -> ArchConfig:
    # [arXiv:2212.04356; unverified] enc-dec, 12L each, d=768, 12H,
    # d_ff=3072, vocab 51865. Conv frontend is a STUB: input_specs()
    # provides precomputed frame embeddings (batch, seq, d_model).
    return ArchConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865, activation="gelu", norm="layernorm",
        use_rope=False,  # whisper uses learned/sinusoidal positions
        encoder_decoder=True, n_encoder_layers=12,
    )


@register("yi-6b")
def yi_6b() -> ArchConfig:
    # [arXiv:2403.04652; hf] llama-arch GQA: 32L d=4096 32H kv=4 d_ff=11008
    return ArchConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab=64000, activation="silu", rope_theta=5000000.0,
    )


@register("minitron-8b")
def minitron_8b() -> ArchConfig:
    # [arXiv:2407.14679; hf] pruned nemotron: 32L d=4096 32H kv=8
    # d_ff=16384 vocab=256000, squared-ReLU like its parent.
    return ArchConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16384, vocab=256000, activation="relu2", head_dim=128,
    )


@register("granite-8b")
def granite_8b() -> ArchConfig:
    # [arXiv:2405.04324; hf] llama-arch code model: 36L d=4096 32H kv=8
    return ArchConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=49152, activation="silu",
    )


@register("nemotron-4-340b")
def nemotron_4_340b() -> ArchConfig:
    # [arXiv:2402.16819; unverified] 96L d=18432 96H kv=8 d_ff=73728
    # vocab=256000, squared-ReLU, no gating. Pure full attention ->
    # long_500k cell is skipped (DESIGN.md §4).
    return ArchConfig(
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_ff=73728, vocab=256000, activation="relu2", head_dim=192,
    )


@register("llama-3.2-vision-90b")
def llama_32_vision_90b() -> ArchConfig:
    # [hf:meta-llama/Llama-3.2-11B-Vision; unverified] 100L d=8192 64H kv=8
    # d_ff=28672 vocab=128256; cross-attn image layers every 5th layer.
    # Vision frontend is a STUB: input_specs() provides patch embeddings.
    return ArchConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128256, activation="silu", rope_theta=500000.0,
        cross_attn_every=5, n_image_tokens=1601,
    )


@register("mamba2-370m")
def mamba2_370m() -> ArchConfig:
    # [arXiv:2405.21060; unverified] SSD: 48L d=1024 attn-free,
    # ssm_state=128, vocab=50280.
    return ArchConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280, activation="silu", use_rope=False,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2,
                      conv_kernel=4, chunk_size=256),
    )


@register("hymba-1.5b")
def hymba_1_5b() -> ArchConfig:
    # [arXiv:2411.13676; hf] 32L d=1600 25H kv=5, d_ff=5504, vocab=32001,
    # ssm_state=16; parallel attn+mamba heads; SWA everywhere except
    # 3 global-attention layers (first/middle/last).
    return ArchConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, activation="silu", head_dim=64,
        hybrid_parallel_heads=True,
        sliding_window=1024, global_attn_layers=(0, 15, 31),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2,
                      conv_kernel=4, chunk_size=256),
    )


ALL_ARCHS = [
    "deepseek-v2-236b", "dbrx-132b", "whisper-small", "yi-6b",
    "minitron-8b", "granite-8b", "nemotron-4-340b",
    "llama-3.2-vision-90b", "mamba2-370m", "hymba-1.5b",
]
