"""Architecture + shape configuration system.

Every assigned architecture is a frozen `ArchConfig`; every workload shape a
`ShapeConfig`. `REGISTRY` maps --arch ids to config constructors, and
`reduced(cfg)` derives the CPU-smoke-test variant (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # first N layers use a dense FFN instead of MoE (DeepSeek-V2 uses 1)
    first_dense_layers: int = 0
    # tokens per routing group (GShard-style grouped dispatch: keeps the
    # one-hot dispatch tensor at O(N * E * cap_per_group) instead of
    # O(N * E * cap_global) — mandatory at 1M-token batches)
    group_size: int = 128
    # "onehot": GShard einsum dispatch (reference); "sort": argsort +
    # scatter/gather dispatch — same math, O(N·K·D) traffic instead of
    # O(N·E·cap·D) (the §Perf optimization for many-expert models)
    dispatch: str = "onehot"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    activation: str = "silu"         # silu(glu) | gelu | relu2
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (hymba): parallel attn + ssm heads per layer
    hybrid_parallel_heads: bool = False
    sliding_window: Optional[int] = None
    global_attn_layers: tuple[int, ...] = ()
    # encoder-decoder (whisper): encoder frontend is a stub (frame embeddings)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # vlm (llama-3.2-vision): cross-attention to image tokens every Nth layer
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    # full attention (no sub-quadratic path) — long_500k is skipped if True
    # (SSM / hybrid / sliding-window archs override)
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic token mixing available (SSM / hybrid / SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def n_params_dense_estimate(self) -> float:
        """Rough parameter count (for 6ND MODEL_FLOPS bookkeeping)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.mla:
            m = self.mla
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads *
                    (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        if self.moe:
            ffn = (self.moe.num_experts + self.moe.num_shared_experts) * \
                  3 * d * self.moe.d_ff_expert
        else:
            mult = 3 if self.activation in ("silu", "geglu") else 2
            ffn = mult * d * self.d_ff
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            ffn = 0
            attn = d * (2 * di + 2 * s.n_groups * s.d_state +
                        s.n_heads(d)) + di * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = L * (attn + ffn) + emb
        if self.encoder_decoder:
            total += self.n_encoder_layers * (attn + ffn)
        return float(total)

    def active_params_estimate(self) -> float:
        """Active (per-token) params — differs from total only for MoE."""
        if not self.moe:
            return self.n_params_dense_estimate
        d, L = self.d_model, self.n_layers
        dense = self.n_params_dense_estimate
        all_experts = (self.moe.num_experts + self.moe.num_shared_experts) * \
                      3 * d * self.moe.d_ff_expert
        active = (self.moe.top_k + self.moe.num_shared_experts) * \
                 3 * d * self.moe.d_ff_expert
        return dense - L * all_experts + L * active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "long_decode")
SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str) -> ArchConfig:
    if name not in REGISTRY:
        # import config modules lazily so `register` decorators run
        from . import all_archs  # noqa: F401
        if name not in REGISTRY:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def list_archs() -> list[str]:
    from . import all_archs  # noqa: F401
    return sorted(REGISTRY)


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Shape cells for an arch, honoring the assignment's skip rules:
    long_500k only for sub-quadratic archs (SSM / hybrid / SWA)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        shapes.append("long_500k")
    return shapes


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        activation=cfg.activation,
        norm=cfg.norm,
        use_rope=cfg.use_rope,
        tie_embeddings=cfg.tie_embeddings,
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(
            num_experts=4, top_k=2, d_ff_expert=32,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
    if cfg.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                              conv_kernel=4, chunk_size=32)
    kw["hybrid_parallel_heads"] = cfg.hybrid_parallel_heads
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    kw["global_attn_layers"] = tuple(i for i in cfg.global_attn_layers if i < 2)
    if cfg.encoder_decoder:
        kw["encoder_decoder"] = True
        kw["n_encoder_layers"] = 2
    if cfg.cross_attn_every:
        kw["cross_attn_every"] = 2
        kw["n_image_tokens"] = 16
    return ArchConfig(**kw)
