"""SOSA core: the paper's contribution (tiling, interconnect, scheduling,
granularity DSE) as a composable library. See DESIGN.md §1/§3."""

from .arrays import (AcceleratorConfig, ArrayConfig, max_pods_under_tdp,
                     monolithic, sosa)
from .interconnect import (ButterflyRouter, IcnSpec, benes_spec,
                           butterfly_spec, crossbar_spec, htree_spec,
                           make_router, mesh_spec, routed_fraction)
from .scheduler import Schedule, SliceScheduler
from .simulator import (BatchedAnalysis, DesignVector, PackedWorkloads,
                        SimResult, analyze, analyze_batch, analyze_scalar,
                        icn_efficiency, merge_workloads, pack_workloads,
                        simulate, sram_spill_bytes)
from .tiling import (GemmSpec, TileOp, TileOpGraph, TileStats, gemm_levels,
                     tile_counts, tile_gemm, tile_stats, tile_workload)

__all__ = [
    "AcceleratorConfig", "ArrayConfig", "max_pods_under_tdp", "monolithic",
    "sosa", "ButterflyRouter", "IcnSpec", "benes_spec", "butterfly_spec",
    "crossbar_spec", "htree_spec", "make_router", "mesh_spec",
    "routed_fraction", "Schedule",
    "SliceScheduler", "SimResult", "analyze", "analyze_scalar",
    "analyze_batch", "BatchedAnalysis", "DesignVector", "PackedWorkloads",
    "icn_efficiency", "pack_workloads", "merge_workloads", "simulate",
    "sram_spill_bytes",
    "GemmSpec", "TileOp", "TileOpGraph", "TileStats", "gemm_levels",
    "tile_counts", "tile_gemm", "tile_stats", "tile_workload",
]
