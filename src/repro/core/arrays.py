"""Systolic-array timing & energy model (SOSA §3.1, §5, Table 2).

This module is the paper's hardware model, calibrated to its published
numbers:

  * PE energy           : 0.4 pJ / MAC  (TSMC 28nm @ 1 GHz, §5)
  * SRAM bank access    : 2.7 pJ / byte (Cacti-P, 256 KB banks, §5)
  * activations/weights : int8 (1 byte), partial sums: int16 (2 bytes)
  * interconnect        : mW/byte-per-cycle from Table 1 (per topology)

A weight-stationary r x c array streams, per cycle, through its *edges*:
  r bytes of activations in, c*2 bytes of partial sums in, c*2 bytes of
  partial sums out, and c bytes of weight prefetch (double buffering).
Hence memory traffic grows linearly with (r + 5c) while compute grows with
r*c — the core of the paper's granularity argument.

Validation (see tests/test_arrays.py): this model reproduces Table 2's
"Peak Power" column to within ~2% for every row, e.g. 113.2 W for the
512x512 monolithic and ~260 W for 256 pods of 32x32 with a Butterfly-2.
"""

from __future__ import annotations

import dataclasses
import math

# --- paper constants (§5) ---------------------------------------------------
E_MAC_PJ = 0.4            # energy per MAC, pJ
E_SRAM_PJ_PER_BYTE = 2.7  # SRAM bank access energy, pJ/byte
CLOCK_HZ = 1e9            # 1 GHz
ACT_BYTES = 1             # int8 activations
WEIGHT_BYTES = 1          # int8 weights
PSUM_BYTES = 2            # int16 partial sums
OPS_PER_MAC = 2           # multiply + add
TDP_WATTS = 400.0         # NVIDIA A100 product-brief TDP used by the paper


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """A weight-stationary systolic array (one pod's compute)."""

    rows: int = 32
    cols: int = 32
    # activation multicast / psum fan-in degrees (§4.1); only affect the
    # pipeline-latency term, not throughput or energy.
    multicast_u: int = 16
    fanin_v: int = 16
    clock_hz: float = CLOCK_HZ

    @property
    def num_pe(self) -> int:
        return self.rows * self.cols

    @property
    def edge_bytes_per_cycle(self) -> float:
        """Bytes crossing the array edge per cycle at full rate.

        acts in (r) + psums in (2c) + psums out (2c) + weight prefetch (c).
        """
        return (
            self.rows * ACT_BYTES
            + self.cols * PSUM_BYTES * 2
            + self.cols * WEIGHT_BYTES
        )

    @property
    def pipeline_latency(self) -> int:
        """Fill/drain latency of one tile op (§4.1): r/U + c/V cycles."""
        return int(
            math.ceil(self.rows / self.multicast_u)
            + math.ceil(self.cols / self.fanin_v)
        )

    # -- power -----------------------------------------------------------
    @property
    def pe_watts(self) -> float:
        return self.num_pe * E_MAC_PJ * 1e-12 * self.clock_hz

    @property
    def sram_watts(self) -> float:
        return self.edge_bytes_per_cycle * E_SRAM_PJ_PER_BYTE * 1e-12 * self.clock_hz

    @property
    def pod_watts(self) -> float:
        """Peak power of one pod, excluding the shared interconnect."""
        return self.pe_watts + self.sram_watts

    # -- throughput --------------------------------------------------------
    @property
    def peak_ops(self) -> float:
        """Peak ops/s (MACs count as 2 ops)."""
        return self.num_pe * OPS_PER_MAC * self.clock_hz

    # -- timing ------------------------------------------------------------
    def tile_exec_cycles(self, k: int) -> int:
        """Streaming cycles for a (k x r') @ (r' x c') tile op.

        Throughput-wise the array consumes one activation row per cycle, so a
        tile with k activation rows takes k cycles + fill/drain latency.
        With double buffering (Ross patent, §3.1) the *next* weight tile
        loads concurrently, taking `rows` cycles; if k < rows the array
        stalls for the remainder — the motivation for the r x r partition.
        """
        return max(k, self.rows) + self.pipeline_latency

    def tile_macs(self, k: int, r_eff: int, c_eff: int) -> int:
        return k * r_eff * c_eff


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """A multi-pod accelerator: N pods + interconnect + banks (Fig 7)."""

    array: ArrayConfig = ArrayConfig()
    num_pods: int = 256
    icn_mw_per_byte: float = 0.52  # Butterfly-2, Table 1
    tdp_watts: float = TDP_WATTS
    sram_bank_kb: int = 256        # §6.4 optimum

    @property
    def peak_watts(self) -> float:
        """Peak power: pods + interconnect moving edge bytes each cycle."""
        pods = self.array.pod_watts * self.num_pods
        icn_bytes_per_cycle = self.array.edge_bytes_per_cycle * self.num_pods
        icn = icn_bytes_per_cycle * self.icn_mw_per_byte * 1e-3
        return pods + icn

    @property
    def peak_ops(self) -> float:
        return self.array.peak_ops * self.num_pods

    @property
    def peak_ops_at_tdp(self) -> float:
        """Peak throughput normalized to the TDP (Table 2 'Peak Throughput
        @400W'): ops/s the design would deliver if scaled isopower to TDP."""
        return self.peak_ops * (self.tdp_watts / self.peak_watts)

    def effective_ops_at_tdp(self, utilization: float) -> float:
        return self.peak_ops_at_tdp * utilization


def max_pods_under_tdp(
    array: ArrayConfig,
    icn_mw_per_byte: float = 0.52,
    tdp_watts: float = TDP_WATTS,
    power_of_two: bool = True,
) -> int:
    """Largest pod count with peak power under TDP (§6 preamble).

    The paper picks the largest power-of-two pod count whose peak power is
    below the 400 W TDP.
    """
    per_pod = (
        array.pod_watts
        + array.edge_bytes_per_cycle * icn_mw_per_byte * 1e-3
    )
    n = max(1, int(tdp_watts // per_pod))
    if power_of_two:
        n = 2 ** int(math.floor(math.log2(n)))
    return n


def monolithic(rows: int, cols: int) -> AcceleratorConfig:
    """A single large array with no inter-pod interconnect (TPUv1-like)."""
    return AcceleratorConfig(
        array=ArrayConfig(rows=rows, cols=cols),
        num_pods=1,
        icn_mw_per_byte=0.0,
    )


def sosa(rows: int = 32, cols: int = 32, num_pods: int | None = None,
         icn_mw_per_byte: float = 0.52,
         tdp_watts: float = TDP_WATTS) -> AcceleratorConfig:
    """The paper's design point: pods sized r x c, pod count set by TDP."""
    arr = ArrayConfig(rows=rows, cols=cols)
    if num_pods is None:
        num_pods = max_pods_under_tdp(arr, icn_mw_per_byte, tdp_watts)
    return AcceleratorConfig(
        array=arr, num_pods=num_pods,
        icn_mw_per_byte=icn_mw_per_byte, tdp_watts=tdp_watts,
    )
