"""Design-space exploration for array granularity (SOSA §3.1, Fig 5, Table 2).

Isopower sweep: for every candidate (rows, cols) the pod count is the
largest power of two under the 400 W TDP (arrays.max_pods_under_tdp), and
the score is effective throughput @ TDP — peak(isopower) x utilization —
averaged over the workload suite weighted equally per benchmark.

Batched engine
--------------
The sweep is evaluated by the batched analytical engine: the whole
(rows x cols x interconnect x workload) grid goes through ONE call of
`simulator.analyze_batch` over a `DesignGrid` (vectorized accelerator
construction, below) and a `PackedWorkloads` (flat per-GEMM arrays).

  * `evaluate_grid(workloads, designs)` — the core batched entry point:
    `designs` is a list of (rows, cols, interconnect, num_pods-or-None)
    tuples; returns one DsePoint per design, each averaged over the suite.
  * `sweep(...)` — the Fig-5 grid, built as a designs list and routed
    through `evaluate_grid`. `sweep_scalar(...)` keeps the original
    per-point Python loop for parity tests and speedup benchmarks
    (benchmarks/dse_map.py reports the ratio).
  * `evaluate_design(...)` / `table2_rows(...)` — thin wrappers over
    `evaluate_grid` (single point / the paper's six Table-2 points, which
    mix interconnects across points — the grid handles that).

The batched path is validated against the scalar path property-based in
tests/test_dse_batch.py, and the analytical model against the
slice-accurate scheduler in tests/test_simulator.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .arrays import (ACT_BYTES, CLOCK_HZ, E_MAC_PJ, E_SRAM_PJ_PER_BYTE,
                     OPS_PER_MAC, PSUM_BYTES, TDP_WATTS, WEIGHT_BYTES,
                     ArrayConfig, AcceleratorConfig, max_pods_under_tdp)
from .interconnect import icn_stage_mw_arrays
from .simulator import (DesignVector, PackedWorkloads, analyze_batch,
                        analyze_scalar, icn_efficiency, pack_workloads)
from .tiling import GemmSpec

# a design is (rows, cols, interconnect, num_pods or None for isopower)
Design = tuple[int, int, str, "int | None"]


@dataclasses.dataclass
class DsePoint:
    rows: int
    cols: int
    num_pods: int
    peak_tops_at_tdp: float
    utilization: float
    effective_tops_at_tdp: float
    effective_tops_per_watt: float


def build_accel(rows: int, cols: int, interconnect: str = "butterfly-2",
                tdp: float = 400.0, num_pods: int | None = None) -> AcceleratorConfig:
    arr = ArrayConfig(rows=rows, cols=cols)
    if num_pods is None:
        # first pass with the 256-port mW/B, then refine for actual count
        mw = _mw_per_byte(interconnect, 256)
        num_pods = max_pods_under_tdp(arr, mw, tdp)
    mw = _mw_per_byte(interconnect, max(2, num_pods))
    return AcceleratorConfig(array=arr, num_pods=num_pods,
                             icn_mw_per_byte=mw if num_pods > 1 else 0.0,
                             tdp_watts=tdp)


def _mw_per_byte(interconnect: str, ports: int) -> float:
    from .simulator import icn_spec_for
    return icn_spec_for(interconnect, ports).mw_per_byte


# ---------------------------------------------------------------------------
# vectorized accelerator construction (build_accel over a designs list)
# ---------------------------------------------------------------------------


def build_design_vector(designs: list[Design],
                        tdp: float = TDP_WATTS) -> DesignVector:
    """`build_accel` + the AcceleratorConfig power/throughput properties,
    vectorized over a designs list — matches the scalar constructors
    element-for-element (same pod-count selection, same isopower peak)."""
    rows = np.array([d[0] for d in designs], dtype=np.int64)
    cols = np.array([d[1] for d in designs], dtype=np.int64)
    icns = [d[2] for d in designs]
    pods_in = [d[3] for d in designs]

    # per-pod power: PEs + SRAM edge traffic (arrays.ArrayConfig properties:
    # acts in (r) + psums in/out (2x2c) + weight prefetch (c))
    edge_bytes = (rows * ACT_BYTES + cols * PSUM_BYTES * 2
                  + cols * WEIGHT_BYTES).astype(np.float64)
    pod_watts = (rows * cols * E_MAC_PJ + edge_bytes * E_SRAM_PJ_PER_BYTE) \
        * 1e-12 * CLOCK_HZ

    num_pods = np.zeros(len(designs), dtype=np.int64)
    icn_mw = np.zeros(len(designs), dtype=np.float64)      # for peak power
    energy_mw = np.zeros(len(designs), dtype=np.float64)   # for energy model
    stages = np.zeros(len(designs), dtype=np.int64)
    eff = np.zeros(len(designs), dtype=np.float64)

    icns_arr = np.array(icns)
    for name in set(icns):
        m = icns_arr == name
        # pod count: explicit, or the largest power of two under TDP using
        # the 256-port mW/B first pass (as build_accel does)
        _, mw0 = icn_stage_mw_arrays(name, np.full(int(m.sum()), 256))
        per_pod = pod_watts[m] + edge_bytes[m] * mw0 * 1e-3
        n = np.maximum(1, np.floor_divide(tdp, per_pod)).astype(np.int64)
        n = 2 ** (np.frexp(n.astype(np.float64))[1] - 1)   # power-of-two floor
        explicit = np.array([p is not None for p in pods_in])[m]
        given = np.array([p if p is not None else 1 for p in pods_in],
                         dtype=np.int64)[m]
        pods = np.where(explicit, given, n)
        num_pods[m] = pods

        ports = np.maximum(2, pods)
        st, mw = icn_stage_mw_arrays(name, ports)
        stages[m] = st
        energy_mw[m] = mw
        icn_mw[m] = np.where(pods > 1, mw, 0.0)            # monolithic: no icn
        eff[m] = icn_efficiency(name)

    peak_watts = pod_watts * num_pods + edge_bytes * num_pods * icn_mw * 1e-3
    peak_ops = rows * cols * OPS_PER_MAC * CLOCK_HZ * num_pods
    defaults = ArrayConfig()  # multicast/fan-in degrees (§4.1)
    pipeline = (-(-rows // defaults.multicast_u)
                + (-(-cols // defaults.fanin_v))).astype(np.int64)

    return DesignVector(
        rows=rows, cols=cols, num_pods=num_pods,
        pipeline_latency=pipeline,
        peak_ops_at_tdp=peak_ops * (tdp / peak_watts),
        icn_stages=stages, icn_energy_mw=energy_mw, icn_eff=eff,
        clock_hz=CLOCK_HZ,
    )


def evaluate_grid(
    workloads: dict[str, list[GemmSpec]] | PackedWorkloads,
    designs: list[Design],
    tdp: float = TDP_WATTS,
    k_part: int | np.ndarray | None = None,
) -> list[DsePoint]:
    """Batched DSE: every design x every workload in one analyze_batch call,
    reduced to one equal-weight DsePoint per design (Table-2 averaging)."""
    dv = build_design_vector(designs, tdp)
    if isinstance(workloads, PackedWorkloads):
        packed = workloads
        n_wl = packed.num_workloads
    else:
        # empty workloads contribute zero metrics but still count in the
        # equal-weight average, exactly like the scalar path
        nonempty = {name: wl for name, wl in workloads.items() if wl}
        n_wl = len(workloads)
        packed = pack_workloads(nonempty) if nonempty else None
    if packed is None:
        return [
            DsePoint(rows=int(dv.rows[p]), cols=int(dv.cols[p]),
                     num_pods=int(dv.num_pods[p]),
                     peak_tops_at_tdp=float(dv.peak_ops_at_tdp[p] / 1e12),
                     utilization=0.0, effective_tops_at_tdp=0.0,
                     effective_tops_per_watt=0.0)
            for p in range(dv.num_points)
        ]
    batch = analyze_batch(packed, dv, k_part=k_part)
    denom = max(1, n_wl)
    util = batch.utilization.sum(axis=1) / denom
    eff = batch.effective_tops_at_tdp.sum(axis=1) / denom
    tpw = batch.effective_tops_per_watt.sum(axis=1) / denom
    return [
        DsePoint(
            rows=int(dv.rows[p]), cols=int(dv.cols[p]),
            num_pods=int(dv.num_pods[p]),
            peak_tops_at_tdp=float(batch.peak_tops_at_tdp[p]),
            utilization=float(util[p]),
            effective_tops_at_tdp=float(eff[p]),
            effective_tops_per_watt=float(tpw[p]),
        )
        for p in range(dv.num_points)
    ]


# ---------------------------------------------------------------------------
# public sweep API (batched), with the scalar path kept for validation
# ---------------------------------------------------------------------------


def evaluate_design(
    rows: int, cols: int,
    workloads: dict[str, list[GemmSpec]],
    interconnect: str = "butterfly-2",
    tdp: float = 400.0,
    num_pods: int | None = None,
) -> DsePoint:
    """One design point — thin wrapper over the batched engine."""
    return evaluate_grid(workloads, [(rows, cols, interconnect, num_pods)],
                         tdp)[0]


def evaluate_design_scalar(
    rows: int, cols: int,
    workloads: dict[str, list[GemmSpec]],
    interconnect: str = "butterfly-2",
    tdp: float = 400.0,
    num_pods: int | None = None,
) -> DsePoint:
    """Original per-workload Python loop over `analyze_scalar`; the oracle
    the batched path is property-tested against (tests/test_dse_batch.py)."""
    accel = build_accel(rows, cols, interconnect, tdp, num_pods)
    # equal-weight average across benchmarks (Table 2 averages the ten
    # benchmarks; ops-weighting would let BERT-large dominate and shift
    # the optimum toward large arrays)
    n = 0
    eff_sum = 0.0
    util_sum = 0.0
    tpw_sum = 0.0
    for name, gemms in workloads.items():
        res = analyze_scalar(gemms, accel, interconnect, name=name)
        n += 1
        util_sum += res.utilization
        eff_sum += res.effective_tops_at_tdp
        tpw_sum += res.effective_tops_per_watt
    n = max(1, n)
    return DsePoint(
        rows=rows, cols=cols, num_pods=accel.num_pods,
        peak_tops_at_tdp=accel.peak_ops_at_tdp / 1e12,
        utilization=util_sum / n,
        effective_tops_at_tdp=eff_sum / n,
        effective_tops_per_watt=tpw_sum / n,
    )


_DEFAULT_ROWS = (8, 16, 20, 32, 48, 64, 66, 128, 256, 512)
_DEFAULT_COLS = (8, 16, 32, 64, 128, 256, 512)


def sweep(
    workloads: dict[str, list[GemmSpec]],
    row_candidates: tuple[int, ...] = _DEFAULT_ROWS,
    col_candidates: tuple[int, ...] = _DEFAULT_COLS,
    interconnect: str = "butterfly-2",
    tdp: float = 400.0,
) -> list[DsePoint]:
    """Fig-5 isopower grid through the batched engine (one call)."""
    designs: list[Design] = [(r, c, interconnect, None)
                             for r in row_candidates for c in col_candidates]
    return evaluate_grid(workloads, designs, tdp)


def sweep_scalar(
    workloads: dict[str, list[GemmSpec]],
    row_candidates: tuple[int, ...] = _DEFAULT_ROWS,
    col_candidates: tuple[int, ...] = _DEFAULT_COLS,
    interconnect: str = "butterfly-2",
    tdp: float = 400.0,
) -> list[DsePoint]:
    """The original double loop (one analyze_scalar per point x workload)."""
    out = []
    for r in row_candidates:
        for c in col_candidates:
            out.append(evaluate_design_scalar(r, c, workloads, interconnect,
                                              tdp))
    return out


def best_point(points: list[DsePoint]) -> DsePoint:
    return max(points, key=lambda p: p.effective_tops_at_tdp)


TABLE2_DESIGNS: tuple[tuple[int, int, int], ...] = (
    (512, 512, 1), (256, 256, 8), (128, 128, 32),
    (64, 64, 128), (16, 16, 512), (32, 32, 256),
)


def table2_rows(workloads: dict[str, list[GemmSpec]],
                tdp: float = 400.0) -> list[DsePoint]:
    """The paper's Table 2 design points (monolithic 512x512 ... 32x32),
    evaluated batched — the grid mixes interconnects across points
    (butterfly-2 pods vs a crossbar-fed monolithic)."""
    designs: list[Design] = [
        (r, c, "butterfly-2" if pods > 1 else "crossbar", pods)
        for (r, c, pods) in TABLE2_DESIGNS
    ]
    return evaluate_grid(workloads, designs, tdp)
