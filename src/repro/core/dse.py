"""Design-space exploration for array granularity (SOSA §3.1, Fig 5, Table 2).

Isopower sweep: for every candidate (rows, cols) the pod count is the
largest power of two under the 400 W TDP (arrays.max_pods_under_tdp), and
the score is effective throughput @ TDP — peak(isopower) x utilization —
averaged over the workload suite weighted by ops.

The sweep uses the analytical wave model (simulator.analyze); selected
design points are cross-checked with the slice-accurate scheduler in
tests/test_simulator.py.
"""

from __future__ import annotations

import dataclasses
import math

from .arrays import ArrayConfig, AcceleratorConfig, max_pods_under_tdp
from .simulator import SimResult, analyze
from .tiling import GemmSpec


@dataclasses.dataclass
class DsePoint:
    rows: int
    cols: int
    num_pods: int
    peak_tops_at_tdp: float
    utilization: float
    effective_tops_at_tdp: float
    effective_tops_per_watt: float


def build_accel(rows: int, cols: int, interconnect: str = "butterfly-2",
                tdp: float = 400.0, num_pods: int | None = None) -> AcceleratorConfig:
    arr = ArrayConfig(rows=rows, cols=cols)
    if num_pods is None:
        # first pass with the 256-port mW/B, then refine for actual count
        mw = _mw_per_byte(interconnect, 256)
        num_pods = max_pods_under_tdp(arr, mw, tdp)
    mw = _mw_per_byte(interconnect, max(2, num_pods))
    return AcceleratorConfig(array=arr, num_pods=num_pods,
                             icn_mw_per_byte=mw if num_pods > 1 else 0.0,
                             tdp_watts=tdp)


def _mw_per_byte(interconnect: str, ports: int) -> float:
    from .simulator import icn_spec_for
    return icn_spec_for(interconnect, ports).mw_per_byte


def evaluate_design(
    rows: int, cols: int,
    workloads: dict[str, list[GemmSpec]],
    interconnect: str = "butterfly-2",
    tdp: float = 400.0,
    num_pods: int | None = None,
) -> DsePoint:
    accel = build_accel(rows, cols, interconnect, tdp, num_pods)
    # equal-weight average across benchmarks (Table 2 averages the ten
    # benchmarks; ops-weighting would let BERT-large dominate and shift
    # the optimum toward large arrays)
    n = 0
    eff_sum = 0.0
    util_sum = 0.0
    tpw_sum = 0.0
    for name, gemms in workloads.items():
        res = analyze(gemms, accel, interconnect, name=name)
        n += 1
        util_sum += res.utilization
        eff_sum += res.effective_tops_at_tdp
        tpw_sum += res.effective_tops_per_watt
    n = max(1, n)
    return DsePoint(
        rows=rows, cols=cols, num_pods=accel.num_pods,
        peak_tops_at_tdp=accel.peak_ops_at_tdp / 1e12,
        utilization=util_sum / n,
        effective_tops_at_tdp=eff_sum / n,
        effective_tops_per_watt=tpw_sum / n,
    )


def sweep(
    workloads: dict[str, list[GemmSpec]],
    row_candidates: tuple[int, ...] = (8, 16, 20, 32, 48, 64, 66, 128, 256, 512),
    col_candidates: tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512),
    interconnect: str = "butterfly-2",
    tdp: float = 400.0,
) -> list[DsePoint]:
    out = []
    for r in row_candidates:
        for c in col_candidates:
            out.append(evaluate_design(r, c, workloads, interconnect, tdp))
    return out


def best_point(points: list[DsePoint]) -> DsePoint:
    return max(points, key=lambda p: p.effective_tops_at_tdp)


def table2_rows(workloads: dict[str, list[GemmSpec]],
                tdp: float = 400.0) -> list[DsePoint]:
    """The paper's Table 2 design points (monolithic 512x512 ... 32x32)."""
    rows = []
    for (r, c, pods) in ((512, 512, 1), (256, 256, 8), (128, 128, 32),
                         (64, 64, 128), (16, 16, 512), (32, 32, 256)):
        # monolithic (pods == 1) gets icn_mw_per_byte = 0 inside build_accel
        icn = "butterfly-2" if pods > 1 else "crossbar"
        rows.append(evaluate_design(r, c, workloads, interconnect=icn,
                                    tdp=tdp, num_pods=pods))
    return rows
