"""Numerical executor: runs a SOSA schedule as real JAX matmuls.

This is the functional proof that the tiling + scheduling pipeline is
correct: executing the scheduled tile ops slice by slice — each op reading
its (i, j) X tile and (j, l) W tile, accumulating into its (i, l) psum
tile exactly when the scheduler says it runs — reproduces X @ W bit-for-bit
(int8 inputs, int32 accumulation like the hardware's wide psums).

`execute_schedule` is deliberately slice-ordered (not a single einsum): it
would produce wrong results if the scheduler ever violated a RAW chain, so
tests/test_executor.py doubles as a scheduler-correctness oracle.
"""

from __future__ import annotations

import numpy as np

from .arrays import ArrayConfig
from .scheduler import Schedule, SliceScheduler
from .tiling import GemmSpec, TileOpGraph, tile_workload


def execute_schedule(
    x: np.ndarray,
    w: np.ndarray,
    graph: TileOpGraph,
    schedule: Schedule,
    array: ArrayConfig,
    k_part: int | None = None,
) -> np.ndarray:
    """Execute the scheduled tile ops of a single GEMM; returns X @ W."""
    d1, d2 = x.shape
    d2b, d3 = w.shape
    assert d2 == d2b
    r, c = array.rows, array.cols
    kp = k_part if k_part is not None else r
    kp = max(1, min(kp, d1))

    acc = np.zeros((d1, d3), dtype=np.int32 if x.dtype == np.int8 else x.dtype)
    # bucket ops by slice and run slices in order
    by_slice: dict[int, list] = {}
    for op in graph.ops:
        sl, _pod = schedule.assignments[op.op_id]
        by_slice.setdefault(sl, []).append(op)
    for sl in sorted(by_slice):
        # within a slice, ops touch disjoint psum tiles (single-ported
        # banks + distinct (i, l)); order inside a slice is irrelevant.
        seen_psums = set()
        for op in by_slice[sl]:
            i0, j0, l0 = op.i * kp, op.j * r, op.l * c
            xt = x[i0:i0 + op.k, j0:j0 + op.r_eff]
            wt = w[j0:j0 + op.r_eff, l0:l0 + op.c_eff]
            key = (op.i, op.l)
            assert key not in seen_psums, "two ops hit one psum tile in a slice"
            seen_psums.add(key)
            acc[i0:i0 + op.k, l0:l0 + op.c_eff] += (
                xt.astype(np.int32) @ wt.astype(np.int32)
            ).astype(acc.dtype)
    return acc


def run_gemm_on_sosa(
    x: np.ndarray,
    w: np.ndarray,
    array: ArrayConfig | None = None,
    num_pods: int = 16,
    interconnect: str = "butterfly-2",
    k_part: int | None = None,
) -> tuple[np.ndarray, Schedule, TileOpGraph]:
    """Tile, schedule and numerically execute one GEMM end to end."""
    array = array or ArrayConfig()
    gemm = GemmSpec(d1=x.shape[0], d2=x.shape[1], d3=w.shape[1], gemm_id=0)
    graph = tile_workload([gemm], array, k_part=k_part, num_banks=num_pods)
    sched = SliceScheduler(
        num_pods=num_pods,
        array_rows=array.rows,
        pipeline_latency=array.pipeline_latency,
        interconnect=interconnect,
    ).schedule(graph)
    out = execute_schedule(x, w, graph, sched, array, k_part=k_part)
    return out, sched, graph
