"""Interconnection networks for multi-pod accelerators (SOSA §3.2, Table 1).

Implements:
  * a *functional* Butterfly-k router — destination-bit routing with k
    parallel expansion planes (Fig 6) and exact edge-conflict detection, used
    by the scheduler to admit or reject a slice's pod<->bank permutation;
  * analytical models (latency in stages/cycles, mW per byte-per-cycle,
    bisection, switch cost) of Butterfly-k / Benes / Crossbar / Mesh / H-tree
    used by the energy model and the interconnect benchmarks.

Cost model: multistage networks are built from 2x2 switches; a message
traverses `stages` of them. We charge energy per byte per switch-stage
(E_SW_PJ_PER_BYTE, calibrated so Butterfly-1 at N=256 lands on Table 1's
0.23 mW/B and Benes on 0.92 mW/B) and a crossbar O(N) per-byte cost matching
7.36 mW/B at N=256.
"""

from __future__ import annotations

import dataclasses
import math
import random

import numpy as np

# Calibration: Table 1 (N = 256 pods).
#   Butterfly-1: log2(256) = 8 stages  -> 0.23 mW/B  => ~0.0288 mW/B/stage
#   Benes: 2*log2(256)-1 = 15 stages, + copy network (multicast, [38])
#          ~log2(256)=8 stages => 23 stages -> 0.92 mW/B? 23*0.0288=0.66.
#          Benes switches are *rearrangeable* (wider datapath control);
#          we charge 1.4x per stage for the control overhead -> 0.92.
E_SW_MW_PER_BYTE_STAGE = 0.23 / 8.0
BENES_STAGE_FACTOR = 1.4
CROSSBAR_MW_PER_BYTE_AT_256 = 7.36


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class IcnSpec:
    name: str
    stages: int              # one-way traversal depth (cycles at 1 switch/cyc)
    mw_per_byte: float       # per byte-per-cycle moved, Table 1 units
    bisection: float         # fraction of N full-rate flows sustainable
    full_permutation: bool   # can route any permutation without blocking
    multicast: bool


def butterfly_paths_conflict(n_bits: int, s1: int, d1: int, s2: int, d2: int) -> bool:
    """Do the unique butterfly paths (s1->d1) and (s2->d2) share an edge?

    MSB-first destination routing: after stage t (t=1..n), the path of
    (s, d) sits at node whose label keeps s's low (n-t) bits and takes d's
    high t bits.  Two paths share the *edge into stage t* iff their node
    labels agree at both t-1 and t.
    """
    if (s1, d1) == (s2, d2):
        return True
    mask_all = (1 << n_bits) - 1
    for t in range(1, n_bits + 1):
        low = n_bits - t
        low_mask = (1 << low) - 1
        hi1 = (d1 >> low) << low
        hi2 = (d2 >> low) << low
        node1 = hi1 | (s1 & low_mask)
        node2 = hi2 | (s2 & low_mask)
        if node1 != node2:
            continue
        # same node entering stage t: they came along the same edge iff they
        # also coincided at stage t-1
        plow = low + 1
        plow_mask = (1 << plow) - 1 if plow <= n_bits else mask_all
        phi1 = (d1 >> plow) << plow if plow <= n_bits else 0
        phi2 = (d2 >> plow) << plow if plow <= n_bits else 0
        pnode1 = phi1 | (s1 & plow_mask)
        pnode2 = phi2 | (s2 & plow_mask)
        if pnode1 == pnode2:
            return True
    return False


class ButterflyRouter:
    """Butterfly-k (expansion-k) functional router over N = 2^n ports.

    Greedy plane assignment: each (src, dst) pair is placed on the first of
    the k planes where its unique path is edge-disjoint from paths already
    placed there. This is the paper's 'redundant switches and links
    facilitated by the expansion' (Fig 6): Butterfly-2 routes permutations a
    standard Butterfly cannot (e.g. the s3->d2 / s6->d3 example).
    """

    def __init__(self, num_ports: int, expansion: int = 2):
        if not _is_pow2(num_ports):
            raise ValueError(f"butterfly needs power-of-two ports, got {num_ports}")
        self.n = num_ports
        self.n_bits = int(math.log2(num_ports))
        self.expansion = expansion

    def _edges(self, s: int, d: int) -> list[tuple[int, int]]:
        """Edge list of the unique path as (stage, node-entering) labels."""
        out = []
        node_prev = s
        for t in range(1, self.n_bits + 1):
            low = self.n_bits - t
            node = ((d >> low) << low) | (s & ((1 << low) - 1))
            out.append((t, (node_prev << self.n_bits) | node))
            node_prev = node
        return out

    def new_planes(self) -> list[dict]:
        """Fresh per-plane edge-ownership state for try_place."""
        return [dict() for _ in range(self.expansion)]

    def try_place(self, planes: list[dict], s: int, d: int) -> bool:
        """Greedily commit (s -> d) to the first plane where its unique
        path is edge-disjoint from paths already placed there. Multicast
        (same src to many dsts) shares edges by definition (copies fork at
        switches), so identical-prefix edges from the same source do not
        conflict; distinct sources must be edge-disjoint. This one helper
        defines the placement semantics for both route() and the
        routed_fraction calibration (the scheduler's incremental probe/
        commit variant lives in scheduler._IncrementalButterfly)."""
        edges = self._edges(s, d)
        for plane in planes:
            if all(plane.get(e) in (None, s) for e in edges):
                for e in edges:
                    plane[e] = s
                return True
        return False

    def route(self, pairs: list[tuple[int, int]]) -> bool:
        """True iff all (src, dst) pairs route conflict-free on k planes."""
        planes = self.new_planes()
        return all(self.try_place(planes, s, d) for s, d in pairs)

    def spec(self) -> IcnSpec:
        return butterfly_spec(self.n, self.expansion)


def butterfly_spec(n: int, k: int) -> IcnSpec:
    stages = int(math.log2(n))
    return IcnSpec(
        name=f"butterfly-{k}",
        stages=stages,
        mw_per_byte=E_SW_MW_PER_BYTE_STAGE * stages * k,
        bisection=1.0 * k,
        full_permutation=False,  # k>=2 is near-full in practice (Table 1)
        multicast=k >= 2,
    )


def benes_spec(n: int, with_copy_network: bool = True) -> IcnSpec:
    """Benes (rearrangeably non-blocking); augmented with a copy network for
    multicast [38], at the price of extra stages (the paper's critique)."""
    stages = 2 * int(math.log2(n)) - 1
    if with_copy_network:
        stages += int(math.log2(n))
    return IcnSpec(
        name="benes",
        stages=stages,
        mw_per_byte=E_SW_MW_PER_BYTE_STAGE * BENES_STAGE_FACTOR * stages,
        bisection=1.0,
        full_permutation=True,
        multicast=with_copy_network,
    )


def crossbar_spec(n: int) -> IcnSpec:
    return IcnSpec(
        name="crossbar",
        stages=2,
        mw_per_byte=CROSSBAR_MW_PER_BYTE_AT_256 * (n / 256.0),
        bisection=1.0,
        full_permutation=True,
        multicast=True,
    )


def mesh_spec(n: int) -> IcnSpec:
    """2D mesh: sqrt(N) average hops, bisection sqrt(N)/N."""
    side = int(math.ceil(math.sqrt(n)))
    return IcnSpec(
        name="mesh",
        stages=side,                       # average-ish hop count
        mw_per_byte=E_SW_MW_PER_BYTE_STAGE * 2 * side,
        bisection=side / n,
        full_permutation=False,
        multicast=False,
    )


def htree_spec(n: int, replication: int = 1) -> IcnSpec:
    """H-tree: log-depth but root-bottlenecked (bisection 1/N per plane);
    scaled-up H-tree replicates it N times at N^2 cost (§3.2)."""
    stages = 2 * int(math.log2(n))
    return IcnSpec(
        name=f"htree-{replication}",
        stages=stages,
        mw_per_byte=E_SW_MW_PER_BYTE_STAGE * stages * replication,
        bisection=replication / n,
        full_permutation=False,
        multicast=True,
    )


def _floor_log2(n: np.ndarray) -> np.ndarray:
    """Exact floor(log2(n)) for positive int64 arrays (via frexp)."""
    _, e = np.frexp(np.asarray(n, dtype=np.int64).astype(np.float64))
    return (e - 1).astype(np.int64)


def icn_stage_mw_arrays(name: str, ports: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(stages, mW/byte-per-cycle) for one topology over an array of port
    counts — the vectorized counterpart of the *_spec constructors above,
    used by the batched DSE engine. Matches them element-for-element."""
    ports = np.asarray(ports, dtype=np.int64)
    if name.startswith("butterfly"):
        k = int(name.split("-")[1]) if "-" in name else 1
        stages = _floor_log2(ports)
        return stages, E_SW_MW_PER_BYTE_STAGE * stages * k
    if name == "benes":
        stages = (2 * _floor_log2(ports) - 1) + _floor_log2(ports)
        return stages, E_SW_MW_PER_BYTE_STAGE * BENES_STAGE_FACTOR * stages
    if name == "crossbar":
        stages = np.full_like(ports, 2)
        return stages, CROSSBAR_MW_PER_BYTE_AT_256 * (ports / 256.0)
    if name == "mesh":
        side = np.ceil(np.sqrt(ports)).astype(np.int64)
        return side, E_SW_MW_PER_BYTE_STAGE * 2 * side
    if name == "htree":  # 'htree-k' is rejected, as in the scalar path
        stages = 2 * _floor_log2(ports)
        return stages, E_SW_MW_PER_BYTE_STAGE * stages.astype(np.float64)
    raise ValueError(f"unknown interconnect: {name}")


def routed_fraction(kind: str, ports: int = 256, samples: int = 8,
                    candidates: int = 8, seed: int = 0) -> float:
    """Measured pod availability of a fabric under the scheduler's traffic.

    Greedily routes `samples` random full-permutation slices through the
    functional router, giving each source the same destination-search width the
    offline scheduler uses (`SliceScheduler` probes up to 8 pod candidates
    per op before bumping the slice). Returns the mean fraction of sources
    that found a conflict-free path — the functional counterpart of
    Table 1's busy-pods column, used to *calibrate* the analytical model's
    `_ICN_EFFICIENCY` instead of hardcoding the paper's ratio
    (simulator.icn_efficiency; regression-pinned to within 5% of Table 1
    in tests/test_tenancy.py).

    Full-permutation fabrics (Benes/Crossbar) route everything by
    construction and return 1.0 without sampling.
    """
    router = make_router(kind, ports)
    if isinstance(router, IdealRouter):
        return 1.0
    rng = random.Random(seed)
    total = 0.0
    for _ in range(samples):
        srcs = list(range(ports))
        dsts = list(range(ports))
        rng.shuffle(srcs)
        rng.shuffle(dsts)
        free = list(dsts)
        planes = router.new_planes()
        placed = 0
        for src in srcs:
            for a in range(min(candidates, len(free))):
                # same deterministic candidate rotation as the scheduler
                ci = (src + a * 37) % len(free)
                if router.try_place(planes, src, free[ci]):
                    free.pop(ci)
                    placed += 1
                    break
        total += placed / ports
    return total / samples


class IdealRouter:
    """Crossbar/Benes functional stand-in: admits any pod<->bank matching
    (both are full-permutation networks); used for Table 1 busy-pods."""

    def __init__(self, num_ports: int, spec: IcnSpec):
        self.n = num_ports
        self._spec = spec

    def route(self, pairs: list[tuple[int, int]]) -> bool:
        return True

    def spec(self) -> IcnSpec:
        return self._spec


def make_router(kind: str, num_ports: int):
    """Factory: 'butterfly-K' | 'benes' | 'crossbar'."""
    if kind.startswith("butterfly"):
        k = int(kind.split("-")[1]) if "-" in kind else 1
        return ButterflyRouter(num_ports, expansion=k)
    if kind == "benes":
        return IdealRouter(num_ports, benes_spec(num_ports))
    if kind == "crossbar":
        return IdealRouter(num_ports, crossbar_spec(num_ports))
    raise ValueError(f"unknown interconnect: {kind}")
