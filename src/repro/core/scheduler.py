"""SOSA offline scheduler (§4.2): tile ops -> (time slice, pod) assignments.

Faithful to the paper:
  * fixed time slices (the tile-op service time; r streaming cycles for the
    r x r partition, plus pipeline latency),
  * greedy earliest-slice placement in tile-op order,
  * three admission constraints per slice:
      (1) RAW dependencies between tile ops (psum chains, layer order),
      (2) single-ported SRAM banks — one tile per bank per network per slice,
          with *multicast* (many pods reading the same tile) allowed when the
          interconnect supports it,
      (3) the interconnect must route the slice's full bank<->pod pattern on
          each of the three networks (X, W, P) — checked with the functional
          Butterfly-k router (exact edge conflicts) or the ideal router for
          full-permutation fabrics (Benes / Crossbar).

Weight double buffering: the W tile for slice l is streamed during slice
l-1; we account its port/route in slice l, which applies identical pressure
shifted by one slice and keeps the search one-pass.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .interconnect import ButterflyRouter, IdealRouter, make_router
from .tiling import TileOp, TileOpGraph


class _IncrementalButterfly:
    """Incremental edge-conflict state for one butterfly plane set (a slice's
    network). probe() finds a feasible plane without mutating state;
    commit() applies it — so a failed multi-network admission leaves the
    slice untouched. O(log N) dict ops per attempt."""

    def __init__(self, router: ButterflyRouter):
        self.r = router
        self.planes: list[dict[tuple[int, int], int]] = [
            dict() for _ in range(router.expansion)
        ]

    def probe(self, s: int, d: int):
        edges = self.r._edges(s, d)
        for pi, plane in enumerate(self.planes):
            ok = True
            for e in edges:
                owner = plane.get(e)
                if owner is not None and owner != s:
                    ok = False
                    break
            if ok:
                return (pi, s, edges)
        return None

    def commit(self, plan) -> None:
        pi, s, edges = plan
        plane = self.planes[pi]
        for e in edges:
            plane[e] = s


class _IncrementalIdeal:
    def __init__(self, router: IdealRouter):
        pass

    def probe(self, s: int, d: int):
        return ()

    def commit(self, plan) -> None:
        pass


def _inc_router(router):
    if isinstance(router, ButterflyRouter):
        return _IncrementalButterfly(router)
    return _IncrementalIdeal(router)


@dataclasses.dataclass
class _SliceState:
    free_pods: list[int]                     # stack of available pod ids
    x_tile: dict[int, tuple]                 # bank -> tile key being read
    w_tile: dict[int, tuple]
    p_busy: set                              # banks with a psum access
    net_x: object = None
    net_w: object = None
    net_p: object = None


@dataclasses.dataclass
class Schedule:
    """Result: op -> (slice, pod), plus topology metadata for the metrics."""

    assignments: dict[int, tuple[int, int]]  # op_id -> (slice_idx, pod)
    num_slices: int
    num_pods: int
    slice_cycles: int                        # service cycles per slice
    routing_retries: int                     # slices skipped due to icn/banks

    def pods_busy_fraction(self) -> float:
        if self.num_slices == 0:
            return 0.0
        return len(self.assignments) / (self.num_slices * self.num_pods)


class SliceScheduler:
    def __init__(
        self,
        num_pods: int,
        array_rows: int,
        pipeline_latency: int,
        interconnect: str = "butterfly-2",
        num_banks: Optional[int] = None,
        faulty_pods: tuple[int, ...] = (),
    ):
        self.num_pods = num_pods
        self.num_banks = num_banks if num_banks is not None else num_pods
        # degraded-pod operation: dead pods are masked out of every slice's
        # free-pod pool (the fabric and bank count are physically unchanged,
        # so routing and ports keep full-machine geometry); busy/utilization
        # fractions keep the full-machine denominator.
        dead = set(faulty_pods)
        if any(p < 0 or p >= num_pods for p in dead):
            raise ValueError(f"faulty_pods {sorted(dead)} out of range "
                             f"for {num_pods} pods")
        self.healthy_pods = [p for p in range(num_pods) if p not in dead]
        if not self.healthy_pods:
            raise ValueError("all pods faulty: nothing to schedule onto")
        self.rows = array_rows
        # slice service time: r streaming cycles (the r x r partition makes
        # every full tile take exactly r cycles) + fill/drain latency.
        self.slice_cycles = array_rows + pipeline_latency
        self.icn_name = interconnect
        # routers are sized to max(pods, banks) ports (N-to-N fabric, §5)
        self.ports = max(self.num_pods, self.num_banks)
        # butterfly needs power-of-two ports
        p = 1
        while p < self.ports:
            p <<= 1
        self.ports = p
        self.router = make_router(interconnect, self.ports)

    def _new_slice(self) -> _SliceState:
        return _SliceState(
            free_pods=list(reversed(self.healthy_pods)),
            x_tile={}, w_tile={}, p_busy=set(),
            net_x=_inc_router(self.router),
            net_w=_inc_router(self.router),
            net_p=_inc_router(self.router),
        )

    def schedule(self, graph: TileOpGraph) -> Schedule:
        slices: list[_SliceState] = []
        placed: dict[int, tuple[int, int]] = {}
        retries = 0

        def ensure(l: int) -> _SliceState:
            while len(slices) <= l:
                slices.append(self._new_slice())
            return slices[l]

        for op in graph.ops:
            ready = 0
            for dep in op.depends_on:
                dslice = placed[dep][0]
                if dslice + 1 > ready:
                    ready = dslice + 1
            l = ready
            while True:
                st = ensure(l)
                # the paper's scheduler searches pod/bank combinations for
                # a routable assignment (§4.2); we try up to `search` pod
                # candidates, rotated by op id so destinations spread over
                # the butterfly's subtrees, before bumping the slice.
                placed_here = False
                search = min(8, len(st.free_pods))
                for a in range(search):
                    ci = (op.op_id + a * 37) % len(st.free_pods)
                    st.free_pods[-1], st.free_pods[ci] = \
                        st.free_pods[ci], st.free_pods[-1]
                    status = self._try_place(st, op)
                    if status == "ok":
                        pod = st.free_pods.pop()
                        placed[op.op_id] = (l, pod)
                        placed_here = True
                        break
                    if status == "bank":
                        break  # structural conflict: other pods won't help
                if placed_here:
                    break
                retries += 1
                l += 1

        return Schedule(
            assignments=placed,
            num_slices=len(slices),
            num_pods=self.num_pods,
            slice_cycles=self.slice_cycles,
            routing_retries=retries,
        )

    def _try_place(self, st: _SliceState, op: TileOp) -> str:
        """'ok' (committed), 'bank' (structural — retrying other pods is
        pointless), or 'route' (this pod's paths conflict)."""
        if not st.free_pods:
            return "bank"
        pod = st.free_pods[-1]

        xkey = (op.gemm_id, "x", op.i, op.j)
        wkey = (op.gemm_id, "w", op.j, op.l)

        # bank port checks (multicast: same tile from same bank is fine iff
        # the fabric multicasts; different tile on a single-ported bank is a
        # structural conflict)
        mc = getattr(self.router.spec(), "multicast", True)
        cur = st.x_tile.get(op.x_bank)
        if cur is not None and (cur != xkey or not mc):
            return "bank"
        curw = st.w_tile.get(op.w_bank)
        if curw is not None and (curw != wkey or not mc):
            return "bank"
        if op.p_bank in st.p_busy:
            return "bank"

        # interconnect admission: banks are sources on X/W, pods on P.
        # Multicast reuses the shared-prefix edges from the same source.
        # probe all three networks, commit only if all admit (no pollution).
        px = st.net_x.probe(op.x_bank % self.ports, pod % self.ports)
        if px is None:
            return "route"
        pw = st.net_w.probe(op.w_bank % self.ports, pod % self.ports)
        if pw is None:
            return "route"
        pp = st.net_p.probe(pod % self.ports, op.p_bank % self.ports)
        if pp is None:
            return "route"
        st.net_x.commit(px)
        st.net_w.commit(pw)
        st.net_p.commit(pp)

        st.x_tile[op.x_bank] = xkey
        st.w_tile[op.w_bank] = wkey
        st.p_busy.add(op.p_bank)
        return "ok"
