"""SOSA performance/energy simulator.

Three evaluation paths over the same tiling model:

  * `simulate(...)`      — slice-accurate: runs the real offline scheduler
    (core/scheduler.py) with the functional Butterfly-k router, bank ports
    and RAW chains, then reduces the schedule to cycles / utilization /
    effective throughput / energy. This is the paper's own methodology
    (their artifact is a cycle-accurate simulator driven by a compiler).

  * `analyze(...)`       — analytical: closed-form wave model of the same
    tiling, used for the Fig-5 design-space sweeps where running the full
    scheduler for every (r, c) point would be needlessly slow. Validated
    against `simulate` in tests (tests/test_simulator.py). Since the
    batched engine landed this is a thin single-point wrapper around
    `analyze_batch`; the original pure-Python closed form survives as
    `analyze_scalar` and serves as the property-test oracle
    (tests/test_dse_batch.py).

  * `analyze_batch(...)` — the batched DSE engine: the same wave model as
    array-shaped NumPy over an entire design grid x workload suite at
    once. Workloads are packed into flat per-GEMM arrays
    (`pack_workloads`), hardware points into a `DesignVector`, and every
    (point, workload) metric falls out of one broadcasted evaluation —
    no per-point Python, which is what makes the Fig-5 grid ~2 orders of
    magnitude faster than the scalar loop.

All report the paper's headline metric, effective throughput @ TDP
(= isopower peak throughput x utilization, Table 2).

Interconnect latency exposure (Table 1 'cycles per tile op'): a slice's
service time is max(k, r) streaming cycles + array fill/drain latency +
any interconnect round-trip not hidden under the streaming time:
    exposed = max(0, 2*stages - max(k, r))
Benes' 2logN-1 (+copy network) stages exceed the 32-cycle tiles and become
exposed — the paper's core argument against it.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from .arrays import (ACT_BYTES, E_MAC_PJ, E_SRAM_PJ_PER_BYTE, OPS_PER_MAC,
                     PSUM_BYTES, WEIGHT_BYTES, AcceleratorConfig)
from .interconnect import (benes_spec, butterfly_spec, crossbar_spec,
                           htree_spec, mesh_spec)
from .scheduler import SliceScheduler
from .tiling import (GemmSpec, TileOpGraph, gemm_levels, tile_counts,
                     tile_workload)


def _faulty_ids(faulty_pods, num_pods: int) -> tuple[int, ...]:
    """Normalize a degraded-pod mask to explicit pod ids.

    An int n masks the n highest-numbered pods (the convention the
    analytical paths price by count alone); a sequence names the dead pods
    directly. Validates 0 <= id < num_pods and at least one survivor."""
    if isinstance(faulty_pods, (int, np.integer)):
        n = int(faulty_pods)
        if not 0 <= n < num_pods:
            raise ValueError(f"faulty_pods={n} out of range for "
                             f"{num_pods} pods")
        return tuple(range(num_pods - n, num_pods))
    ids = tuple(sorted(set(int(p) for p in faulty_pods)))
    if any(p < 0 or p >= num_pods for p in ids):
        raise ValueError(f"faulty_pods {list(ids)} out of range for "
                         f"{num_pods} pods")
    if len(ids) >= num_pods:
        raise ValueError("all pods faulty: nothing to run on")
    return ids


def _faulty_count(faulty_pods) -> int:
    """Number of dead pods in a mask (int passes through)."""
    if isinstance(faulty_pods, (int, np.integer)):
        return int(faulty_pods)
    return len(set(int(p) for p in faulty_pods))


def icn_spec_for(name: str, ports: int):
    if name.startswith("butterfly"):
        k = int(name.split("-")[1]) if "-" in name else 1
        return butterfly_spec(ports, k)
    return {
        "benes": benes_spec, "crossbar": crossbar_spec,
        "mesh": mesh_spec, "htree": htree_spec,
    }[name](ports)


@dataclasses.dataclass
class SimResult:
    name: str
    total_macs: int
    total_cycles: int
    num_pods: int
    utilization: float            # useful MACs / (PEs * cycles)
    busy_pods: float              # fraction of pod-slices with work
    cycles_per_tile: float        # avg service latency per tile op
    effective_tops_at_tdp: float  # the paper's headline metric
    peak_tops_at_tdp: float
    energy_joules: float
    avg_power_watts: float
    num_tile_ops: int
    num_slices: int

    @property
    def effective_tops_per_watt(self) -> float:
        if self.avg_power_watts == 0:
            return 0.0
        macs_per_s = self.total_macs / (self.total_cycles / 1e9)
        return macs_per_s * OPS_PER_MAC / 1e12 / self.avg_power_watts


def _slice_cycles(accel: AcceleratorConfig, icn_name: str, k_bar: float) -> float:
    """Service cycles per slice: streaming + fill/drain + exposed icn."""
    arr = accel.array
    stream = max(k_bar, arr.rows)
    spec = icn_spec_for(icn_name, max(2, accel.num_pods))
    exposed = max(0.0, 2 * spec.stages - stream)
    return stream + arr.pipeline_latency + exposed


def _energy(accel: AcceleratorConfig, graph: TileOpGraph, icn_name: str,
            total_cycles: float) -> tuple[float, float]:
    """(energy J, avg power W): MAC energy + bank bytes + interconnect."""
    arr = accel.array
    spec = icn_spec_for(icn_name, max(2, accel.num_pods))
    e = 0.0
    for op in graph.ops:
        e += op.macs * E_MAC_PJ
        xbytes = op.k * op.r_eff * ACT_BYTES
        wbytes = op.r_eff * op.c_eff * WEIGHT_BYTES
        pbytes = op.k * op.c_eff * PSUM_BYTES * (2 if op.j > 0 else 1)
        moved = xbytes + wbytes + pbytes
        e += moved * (E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)  # pJ (mW/B @1GHz == pJ/B)
    e *= 1e-12
    t = total_cycles / arr.clock_hz
    return e, (e / t if t > 0 else 0.0)


def simulate(
    gemms: list[GemmSpec],
    accel: AcceleratorConfig,
    interconnect: str = "butterfly-2",
    k_part: int | None = None,
    name: str = "",
    faulty_pods=0,
) -> SimResult:
    """Slice-accurate simulation: tile -> schedule -> metrics.

    faulty_pods (int count or sequence of pod ids) retiles and reschedules
    over the survivors: a dead pod takes its local SRAM bank group with it
    (matching bank masks in tile_workload) while fabric geometry and the
    full-machine utilization denominator stay fixed."""
    arr = accel.array
    dead = _faulty_ids(faulty_pods, accel.num_pods)
    graph = tile_workload(gemms, arr, k_part=k_part,
                          num_banks=accel.num_pods, faulty_banks=dead)
    sched = SliceScheduler(
        num_pods=accel.num_pods,
        array_rows=arr.rows,
        pipeline_latency=arr.pipeline_latency,
        interconnect=interconnect,
        faulty_pods=dead,
    ).schedule(graph)

    k_bar = (sum(op.k for op in graph.ops) / len(graph.ops)) if graph.ops else arr.rows
    slice_cyc = _slice_cycles(accel, interconnect, k_bar)
    total_cycles = sched.num_slices * slice_cyc
    total_macs = graph.total_macs
    util = total_macs / (accel.num_pods * arr.num_pe * total_cycles) if total_cycles else 0.0
    energy, power = _energy(accel, graph, interconnect, total_cycles)
    return SimResult(
        name=name,
        total_macs=total_macs,
        total_cycles=int(total_cycles),
        num_pods=accel.num_pods,
        utilization=util,
        busy_pods=sched.pods_busy_fraction(),
        cycles_per_tile=slice_cyc,
        effective_tops_at_tdp=accel.peak_ops_at_tdp * util / 1e12,
        peak_tops_at_tdp=accel.peak_ops_at_tdp / 1e12,
        energy_joules=energy,
        avg_power_watts=power,
        num_tile_ops=len(graph.ops),
        num_slices=sched.num_slices,
    )


# ---------------------------------------------------------------------------
# analytical wave model (fast path for the Fig-5 DSE sweeps)
# ---------------------------------------------------------------------------

# relative pod-availability per fabric (Table 1 busy-pods, normalized to the
# full-permutation fabrics); only Butterfly-1's limited combinatorial power
# costs throughput. Butterfly-1's ratio is *calibrated* from the functional
# router (interconnect.routed_fraction) on first use rather than hardcoded
# from the paper; the measured value is regression-pinned to within 5% of
# Table 1's 66.81/72.41 in tests/test_tenancy.py.
_ICN_EFFICIENCY = {
    "butterfly-2": 1.0, "butterfly-4": 1.0, "butterfly-8": 1.0,
    "crossbar": 1.0, "benes": 1.0, "mesh": 0.55, "htree": 0.45,
}
_CALIBRATED_ICN = ("butterfly-1",)


def icn_efficiency(name: str) -> float:
    """Busy-pod efficiency of a fabric for the analytical wave model.

    Fabrics with restricted combinatorial power are measured against the
    functional router under the scheduler's own traffic model (random
    permutation slices with the 8-candidate destination search) and
    normalized to the corresponding full-permutation fabric — here,
    Butterfly-1 relative to Butterfly-2. The result is cached module-wide;
    every other fabric keeps its Table-1 value.
    """
    if name in _CALIBRATED_ICN and name not in _ICN_EFFICIENCY:
        from .interconnect import routed_fraction
        k = int(name.split("-")[1])
        _ICN_EFFICIENCY[name] = (routed_fraction(name)
                                 / routed_fraction(f"butterfly-{2 * k}"))
    return _ICN_EFFICIENCY.get(name, 1.0)


def _levels(gemms: list[GemmSpec]) -> list[list[GemmSpec]]:
    """Group layers into topological levels (parallel branches share one).

    Thin wrapper over tiling.gemm_levels — one leveling rule for the
    scalar oracle, the batched engine, and the memory-sweep benchmark."""
    depth = gemm_levels(gemms)
    lv: dict[int, list[GemmSpec]] = defaultdict(list)
    for i in sorted(range(len(gemms)), key=lambda i: gemms[i].gemm_id):
        lv[int(depth[i])].append(gemms[i])
    return [lv[i] for i in sorted(lv)]


def analyze_scalar(
    gemms: list[GemmSpec],
    accel: AcceleratorConfig,
    interconnect: str = "butterfly-2",
    k_part: int | None = None,
    name: str = "",
    faulty_pods=0,
) -> SimResult:
    """Closed-form wave model of the tiled schedule (pure-Python reference).

    Per level: every GEMM contributes ceil(d1/k)*ceil(d3/c) independent
    psum chains of length ceil(d2/r). Chains from all GEMMs of the level
    run concurrently in waves of `pods` (scaled by the fabric's busy-pod
    efficiency); the level cannot finish faster than its longest chain.

    This is the original scalar implementation, kept verbatim as the
    independent oracle for the batched engine (`analyze_batch`); use
    `analyze` for single points — it routes through the batched engine.
    """
    arr = accel.array
    r, c = arr.rows, arr.cols
    kp = k_part if k_part is not None else r
    # degraded pods shrink the wave width only: the fabric, bank count and
    # the peak/utilization denominators keep full-machine geometry
    _faulty_ids(faulty_pods, accel.num_pods)      # validate
    healthy = accel.num_pods - _faulty_count(faulty_pods)
    eff_pods = healthy * icn_efficiency(interconnect)

    total_macs = 0
    total_slices = 0.0
    total_tiles = 0
    k_sum = 0.0
    for level in _levels(gemms):
        pod_slices = 0.0
        crit = 0.0
        for g in level:
            kpg = max(1, min(kp, g.d1))
            n_i = math.ceil(g.d1 / kpg)
            n_j = math.ceil(g.d2 / r)
            n_l = math.ceil(g.d3 / c)
            pod_slices += n_i * n_j * n_l
            crit = max(crit, n_j)
            total_macs += g.macs
            total_tiles += n_i * n_j * n_l
            k_sum += n_i * n_j * n_l * (g.d1 / n_i)
        total_slices += max(crit, pod_slices / eff_pods)

    k_bar = (k_sum / total_tiles) if total_tiles else r
    slice_cyc = _slice_cycles(accel, interconnect, k_bar)
    total_cycles = total_slices * slice_cyc
    util = total_macs / (accel.num_pods * arr.num_pe * total_cycles) if total_cycles else 0.0
    busy = total_tiles / (total_slices * accel.num_pods) if total_slices else 0.0

    # energy: same accounting as the slice-accurate path without scheduling
    spec = icn_spec_for(interconnect, max(2, accel.num_pods))
    e_pj = 0.0
    for g in gemms:
        n_j = math.ceil(g.d2 / r)
        e_pj += g.macs * E_MAC_PJ
        e_pj += g.d1 * g.d2 * ACT_BYTES * (E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)
        e_pj += g.d2 * g.d3 * WEIGHT_BYTES * (E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)
        e_pj += g.d1 * g.d3 * PSUM_BYTES * (2 * n_j - 1) * (
            E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)
    energy = e_pj * 1e-12
    t = total_cycles / arr.clock_hz if total_cycles else 0.0
    power = energy / t if t > 0 else 0.0

    return SimResult(
        name=name,
        total_macs=total_macs,
        total_cycles=int(total_cycles),
        num_pods=accel.num_pods,
        utilization=util,
        busy_pods=min(1.0, busy),
        cycles_per_tile=slice_cyc,
        effective_tops_at_tdp=accel.peak_ops_at_tdp * util / 1e12,
        peak_tops_at_tdp=accel.peak_ops_at_tdp / 1e12,
        energy_joules=energy,
        avg_power_watts=power,
        num_tile_ops=total_tiles,
        num_slices=int(total_slices),
    )


# ---------------------------------------------------------------------------
# batched DSE engine: the wave model as array-shaped NumPy over a whole
# (design point x workload) grid in one call
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedWorkloads:
    """A workload suite flattened into per-GEMM arrays for batched analysis.

    GEMMs are sorted by (workload, level) so both the per-level wave
    reduction and the per-workload totals are contiguous-segment reductions
    (np.ufunc.reduceat) — no Python per-GEMM loop anywhere downstream.
    """

    names: tuple[str, ...]
    d1: np.ndarray             # (G,) int64, G = total GEMMs across workloads
    d2: np.ndarray
    d3: np.ndarray
    macs: np.ndarray           # (G,) d1*d2*d3
    seg_starts: np.ndarray     # (S,) first GEMM of each (workload, level)
    wl_seg_starts: np.ndarray  # (W,) first segment of each workload
    wl_gemm_starts: np.ndarray  # (W,) first GEMM of each workload

    @property
    def num_workloads(self) -> int:
        return len(self.names)

    def level_working_set_bytes(self) -> np.ndarray:
        """(S,) SRAM working set per (workload, level) segment: live
        activation tiles + double-buffered weights + int16 psum tiles —
        the same per-level accounting benchmarks/memory_sweep.py originally
        ran as a Python loop, as one reduceat over the packed arrays."""
        ws = (self.d1 * self.d2 * ACT_BYTES
              + 2 * self.d2 * self.d3 * WEIGHT_BYTES
              + self.d1 * self.d3 * PSUM_BYTES)
        return np.add.reduceat(ws, self.seg_starts)


def pack_workloads(
    workloads: dict[str, list[GemmSpec]] | list[list[GemmSpec]],
) -> PackedWorkloads:
    """Flatten a workload suite into reduceat-ready arrays (see above)."""
    if isinstance(workloads, dict):
        items = list(workloads.items())
    else:
        items = [(f"wl{i}", wl) for i, wl in enumerate(workloads)]
    if not items or any(not wl for _, wl in items):
        raise ValueError("pack_workloads needs at least one non-empty workload")

    names: list[str] = []
    d1: list[np.ndarray] = []
    d2: list[np.ndarray] = []
    d3: list[np.ndarray] = []
    seg_starts: list[int] = []
    wl_seg_starts: list[int] = []
    wl_gemm_starts: list[int] = []
    g_off = 0
    for name, wl in items:
        names.append(name)
        lv = gemm_levels(wl)
        order = np.argsort(lv, kind="stable")
        lv = lv[order]
        d1.append(np.array([wl[i].d1 for i in order], dtype=np.int64))
        d2.append(np.array([wl[i].d2 for i in order], dtype=np.int64))
        d3.append(np.array([wl[i].d3 for i in order], dtype=np.int64))
        wl_seg_starts.append(len(seg_starts))
        wl_gemm_starts.append(g_off)
        # level-segment boundaries within this workload
        bounds = np.flatnonzero(np.r_[True, lv[1:] != lv[:-1]]) + g_off
        seg_starts.extend(bounds.tolist())
        g_off += len(wl)

    d1a = np.concatenate(d1)
    d2a = np.concatenate(d2)
    d3a = np.concatenate(d3)
    return PackedWorkloads(
        names=tuple(names), d1=d1a, d2=d2a, d3=d3a, macs=d1a * d2a * d3a,
        seg_starts=np.asarray(seg_starts, dtype=np.int64),
        wl_seg_starts=np.asarray(wl_seg_starts, dtype=np.int64),
        wl_gemm_starts=np.asarray(wl_gemm_starts, dtype=np.int64),
    )


def sram_spill_bytes(packed: PackedWorkloads, sram_bytes) -> np.ndarray:
    """Per-workload bytes spilled to DRAM over a grid of SRAM capacities.

    `sram_bytes` is a scalar or (B,) array of total on-chip capacities
    (banks x bank size); each (workload, level) working set beyond capacity
    spills (Fig 13 / §6.4 model). Returns (B, W) — with the capacities axis
    broadcast, the whole (bank-size x design) sweep needs just one
    `analyze_batch` call for the compute side (benchmarks/memory_sweep.py).
    """
    ws = packed.level_working_set_bytes().astype(np.float64)      # (S,)
    cap = np.atleast_1d(np.asarray(sram_bytes, dtype=np.float64))
    spill = np.maximum(0.0, ws[None, :] - cap[:, None])           # (B, S)
    return np.add.reduceat(spill, packed.wl_seg_starts, axis=1)   # (B, W)


@dataclasses.dataclass(frozen=True)
class DesignVector:
    """Per-design-point hardware quantities, shape (P,) each — everything
    the wave model needs, with the interconnect spec already resolved."""

    rows: np.ndarray               # int64
    cols: np.ndarray
    num_pods: np.ndarray
    pipeline_latency: np.ndarray   # int64, fill/drain cycles
    peak_ops_at_tdp: np.ndarray    # float64, ops/s isopower-normalized
    icn_stages: np.ndarray         # int64, one-way traversal depth
    icn_energy_mw: np.ndarray      # float64, spec mW/B for the energy model
    icn_eff: np.ndarray            # float64, busy-pod efficiency (Table 1)
    clock_hz: float = 1e9

    @property
    def num_points(self) -> int:
        return len(self.rows)

    def repeat(self, n: int) -> "DesignVector":
        """The same design point replicated n times (e.g. to sweep a
        per-point parameter like k_part over fixed hardware)."""
        return DesignVector(
            rows=np.repeat(self.rows, n), cols=np.repeat(self.cols, n),
            num_pods=np.repeat(self.num_pods, n),
            pipeline_latency=np.repeat(self.pipeline_latency, n),
            peak_ops_at_tdp=np.repeat(self.peak_ops_at_tdp, n),
            icn_stages=np.repeat(self.icn_stages, n),
            icn_energy_mw=np.repeat(self.icn_energy_mw, n),
            icn_eff=np.repeat(self.icn_eff, n),
            clock_hz=self.clock_hz,
        )

    @classmethod
    def from_accel(cls, accel: AcceleratorConfig,
                   interconnect: str = "butterfly-2") -> "DesignVector":
        """Single-point vector from a config object (exact scalar specs)."""
        arr = accel.array
        spec = icn_spec_for(interconnect, max(2, accel.num_pods))
        as1 = lambda v, dt: np.asarray([v], dtype=dt)  # noqa: E731
        return cls(
            rows=as1(arr.rows, np.int64), cols=as1(arr.cols, np.int64),
            num_pods=as1(accel.num_pods, np.int64),
            pipeline_latency=as1(arr.pipeline_latency, np.int64),
            peak_ops_at_tdp=as1(accel.peak_ops_at_tdp, np.float64),
            icn_stages=as1(spec.stages, np.int64),
            icn_energy_mw=as1(spec.mw_per_byte, np.float64),
            icn_eff=as1(icn_efficiency(interconnect), np.float64),
            clock_hz=arr.clock_hz,
        )


@dataclasses.dataclass(frozen=True)
class BatchedAnalysis:
    """`analyze` over a (P design points x W workloads) grid; every metric
    array is shaped (P, W) unless noted."""

    names: tuple[str, ...]
    design: DesignVector
    total_macs: np.ndarray             # (W,)
    total_cycles: np.ndarray           # float; int-truncated on materialize
    num_slices: np.ndarray
    level_slices: np.ndarray           # (P, S) wave count per (wl, level)
                                       # segment — tenancy/planner.py reads
                                       # per-tenant completion out of these
    num_tile_ops: np.ndarray
    utilization: np.ndarray
    busy_pods: np.ndarray
    cycles_per_tile: np.ndarray
    effective_tops_at_tdp: np.ndarray
    peak_tops_at_tdp: np.ndarray       # (P,)
    energy_joules: np.ndarray
    avg_power_watts: np.ndarray

    @property
    def effective_tops_per_watt(self) -> np.ndarray:
        """(P, W), same int-cycle truncation as SimResult's property."""
        cyc = np.maximum(1.0, np.floor(self.total_cycles))
        macs_per_s = self.total_macs[None, :] / (cyc / 1e9)
        with np.errstate(divide="ignore", invalid="ignore"):
            out = macs_per_s * OPS_PER_MAC / 1e12 / self.avg_power_watts
        return np.where(self.avg_power_watts > 0, out, 0.0)

    def result(self, p: int, w: int = 0, name: str | None = None) -> SimResult:
        """Materialize one grid cell as a scalar SimResult."""
        return SimResult(
            name=self.names[w] if name is None else name,
            total_macs=int(self.total_macs[w]),
            total_cycles=int(self.total_cycles[p, w]),
            num_pods=int(self.design.num_pods[p]),
            utilization=float(self.utilization[p, w]),
            busy_pods=float(self.busy_pods[p, w]),
            cycles_per_tile=float(self.cycles_per_tile[p, w]),
            effective_tops_at_tdp=float(self.effective_tops_at_tdp[p, w]),
            peak_tops_at_tdp=float(self.peak_tops_at_tdp[p]),
            energy_joules=float(self.energy_joules[p, w]),
            avg_power_watts=float(self.avg_power_watts[p, w]),
            num_tile_ops=int(self.num_tile_ops[p, w]),
            num_slices=int(self.num_slices[p, w]),
        )


def analyze_batch(
    packed: PackedWorkloads,
    design: DesignVector,
    k_part: int | np.ndarray | None = None,
    faulty_pods: int | np.ndarray = 0,
) -> BatchedAnalysis:
    """The closed-form wave model, broadcast over the full grid.

    Shapes: P design points, G GEMMs (all workloads concatenated),
    S (workload, level) segments, W workloads. The per-GEMM intermediates
    are (P, G); reduceat folds them to (P, S) level waves and then (P, W)
    workload totals. `k_part` may be a scalar (applied everywhere), an
    array of shape (P,) (per-point activation partition — used by the
    tiling sweep), or None for the paper's k = rows rule.

    `faulty_pods` (scalar count or (P,) per-point counts) shrinks the wave
    width to the surviving pods while keeping the fabric spec and the
    peak/utilization denominators at full-machine geometry — predictions
    are therefore monotone non-increasing in masked pods by construction
    (eff_pods only ever enters as a divisor under a max with the RAW
    critical path).
    """
    d1, d2, d3 = packed.d1[None, :], packed.d2[None, :], packed.d3[None, :]
    r = design.rows[:, None]
    c = design.cols[:, None]

    if k_part is None:
        kp = r
    else:
        kp = np.asarray(k_part, dtype=np.int64)
        # scalar -> everywhere; (P,)/(P,1) -> per design point
        kp = kp.reshape(1, 1) if kp.ndim == 0 else kp.reshape(-1, 1)
    n_i, n_j, n_l = tile_counts(d1, d2, d3, r, c, kp)
    tiles = n_i * n_j * n_l                      # (P, G)

    # wave count per (workload, level) segment: waves of eff_pods concurrent
    # chains, floored by the longest RAW chain of the level; degraded pods
    # narrow the wave (survivors only)
    f = np.asarray(faulty_pods, dtype=np.int64)
    healthy = design.num_pods - f                # (P,) by broadcast
    if np.any(f < 0) or np.any(healthy < 1):
        raise ValueError("faulty_pods must satisfy 0 <= f < num_pods "
                         "at every design point")
    eff_pods = (healthy * design.icn_eff)[:, None]
    pod_slices = np.add.reduceat(tiles, packed.seg_starts, axis=1)
    crit = np.maximum.reduceat(n_j, packed.seg_starts, axis=1)
    level_slices = np.maximum(crit, pod_slices / eff_pods)   # (P, S)

    ws = packed.wl_seg_starts
    wg = packed.wl_gemm_starts
    total_slices = np.add.reduceat(level_slices, ws, axis=1)  # (P, W)
    total_tiles = np.add.reduceat(tiles, wg, axis=1)
    k_sum = np.add.reduceat(tiles * (d1 / n_i), wg, axis=1)
    total_macs = np.add.reduceat(packed.macs, wg)             # (W,)

    # slice service time: streaming + fill/drain + exposed interconnect
    k_bar = k_sum / total_tiles
    stream = np.maximum(k_bar, r)
    exposed = np.maximum(0.0, 2 * design.icn_stages[:, None] - stream)
    slice_cyc = stream + design.pipeline_latency[:, None] + exposed  # (P, W)

    total_cycles = total_slices * slice_cyc
    num_pe = (design.rows * design.cols * design.num_pods)[:, None]
    util = total_macs[None, :] / (num_pe * total_cycles)
    busy = np.minimum(1.0, total_tiles / (total_slices * design.num_pods[:, None]))

    # energy: same accounting as analyze_scalar, in one (P, G) pass
    e_per_b = E_SRAM_PJ_PER_BYTE + design.icn_energy_mw[:, None]
    e_pj = (
        packed.macs[None, :] * E_MAC_PJ
        + (d1 * d2 * ACT_BYTES + d2 * d3 * WEIGHT_BYTES) * e_per_b
        + d1 * d3 * PSUM_BYTES * (2 * n_j - 1) * e_per_b
    )
    energy = np.add.reduceat(e_pj, wg, axis=1) * 1e-12        # (P, W) joules
    t = total_cycles / design.clock_hz
    power = energy / t

    return BatchedAnalysis(
        names=packed.names,
        design=design,
        total_macs=total_macs,
        total_cycles=total_cycles,
        num_slices=total_slices.astype(np.int64),
        level_slices=level_slices,
        num_tile_ops=total_tiles,
        utilization=util,
        busy_pods=busy,
        cycles_per_tile=slice_cyc,
        effective_tops_at_tdp=design.peak_ops_at_tdp[:, None] * util / 1e12,
        peak_tops_at_tdp=design.peak_ops_at_tdp / 1e12,
        energy_joules=energy,
        avg_power_watts=power,
    )


def analyze(
    gemms: list[GemmSpec],
    accel: AcceleratorConfig,
    interconnect: str = "butterfly-2",
    k_part: int | None = None,
    name: str = "",
    faulty_pods=0,
) -> SimResult:
    """Closed-form wave model of the tiled schedule (see `analyze_scalar`
    for the math) — thin single-point wrapper over the batched engine."""
    if not gemms:
        return analyze_scalar(gemms, accel, interconnect, k_part, name,
                              faulty_pods=faulty_pods)
    packed = pack_workloads({name or "workload": gemms})
    design = DesignVector.from_accel(accel, interconnect)
    batch = analyze_batch(packed, design, k_part=k_part,
                          faulty_pods=_faulty_count(faulty_pods))
    return batch.result(0, 0, name=name)


def merge_workloads(*workloads: list[GemmSpec]) -> list[GemmSpec]:
    """Multi-tenancy (§6.1): co-schedule independent workloads. GEMM ids are
    re-based so streams stay dependency-disjoint and interleave freely.

    This is the primitive under repro.tenancy (TenantMix.merged wraps it;
    the batched planner evaluates whole grids of merged co-schedules, and
    benchmarks/multitenancy.py keeps this + analyze_scalar as the oracle)."""
    merged: list[GemmSpec] = []
    base = 0
    for wl in workloads:
        for g in wl:
            merged.append(GemmSpec(
                d1=g.d1, d2=g.d2, d3=g.d3,
                gemm_id=g.gemm_id + base,
                depends_on=tuple(d + base for d in g.depends_on),
                name=g.name,
            ))
        base += (max((g.gemm_id for g in wl), default=0) + 1)
    return merged
