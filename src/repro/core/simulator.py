"""SOSA performance/energy simulator.

Two evaluation paths over the same tiling model:

  * `simulate(...)`  — slice-accurate: runs the real offline scheduler
    (core/scheduler.py) with the functional Butterfly-k router, bank ports
    and RAW chains, then reduces the schedule to cycles / utilization /
    effective throughput / energy. This is the paper's own methodology
    (their artifact is a cycle-accurate simulator driven by a compiler).

  * `analyze(...)`   — analytical: closed-form wave model of the same
    tiling, used for the Fig-5 design-space sweeps where running the full
    scheduler for every (r, c) point would be needlessly slow. Validated
    against `simulate` in tests (tests/test_simulator.py).

Both report the paper's headline metric, effective throughput @ TDP
(= isopower peak throughput x utilization, Table 2).

Interconnect latency exposure (Table 1 'cycles per tile op'): a slice's
service time is max(k, r) streaming cycles + array fill/drain latency +
any interconnect round-trip not hidden under the streaming time:
    exposed = max(0, 2*stages - max(k, r))
Benes' 2logN-1 (+copy network) stages exceed the 32-cycle tiles and become
exposed — the paper's core argument against it.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

from .arrays import (ACT_BYTES, E_MAC_PJ, E_SRAM_PJ_PER_BYTE, OPS_PER_MAC,
                     PSUM_BYTES, WEIGHT_BYTES, AcceleratorConfig)
from .interconnect import (benes_spec, butterfly_spec, crossbar_spec,
                           htree_spec, mesh_spec)
from .scheduler import SliceScheduler
from .tiling import GemmSpec, TileOpGraph, tile_workload


def icn_spec_for(name: str, ports: int):
    if name.startswith("butterfly"):
        k = int(name.split("-")[1]) if "-" in name else 1
        return butterfly_spec(ports, k)
    return {
        "benes": benes_spec, "crossbar": crossbar_spec,
        "mesh": mesh_spec, "htree": htree_spec,
    }[name](ports)


@dataclasses.dataclass
class SimResult:
    name: str
    total_macs: int
    total_cycles: int
    num_pods: int
    utilization: float            # useful MACs / (PEs * cycles)
    busy_pods: float              # fraction of pod-slices with work
    cycles_per_tile: float        # avg service latency per tile op
    effective_tops_at_tdp: float  # the paper's headline metric
    peak_tops_at_tdp: float
    energy_joules: float
    avg_power_watts: float
    num_tile_ops: int
    num_slices: int

    @property
    def effective_tops_per_watt(self) -> float:
        if self.avg_power_watts == 0:
            return 0.0
        macs_per_s = self.total_macs / (self.total_cycles / 1e9)
        return macs_per_s * OPS_PER_MAC / 1e12 / self.avg_power_watts


def _slice_cycles(accel: AcceleratorConfig, icn_name: str, k_bar: float) -> float:
    """Service cycles per slice: streaming + fill/drain + exposed icn."""
    arr = accel.array
    stream = max(k_bar, arr.rows)
    spec = icn_spec_for(icn_name, max(2, accel.num_pods))
    exposed = max(0.0, 2 * spec.stages - stream)
    return stream + arr.pipeline_latency + exposed


def _energy(accel: AcceleratorConfig, graph: TileOpGraph, icn_name: str,
            total_cycles: float) -> tuple[float, float]:
    """(energy J, avg power W): MAC energy + bank bytes + interconnect."""
    arr = accel.array
    spec = icn_spec_for(icn_name, max(2, accel.num_pods))
    e = 0.0
    for op in graph.ops:
        e += op.macs * E_MAC_PJ
        xbytes = op.k * op.r_eff * ACT_BYTES
        wbytes = op.r_eff * op.c_eff * WEIGHT_BYTES
        pbytes = op.k * op.c_eff * PSUM_BYTES * (2 if op.j > 0 else 1)
        moved = xbytes + wbytes + pbytes
        e += moved * (E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)  # pJ (mW/B @1GHz == pJ/B)
    e *= 1e-12
    t = total_cycles / arr.clock_hz
    return e, (e / t if t > 0 else 0.0)


def simulate(
    gemms: list[GemmSpec],
    accel: AcceleratorConfig,
    interconnect: str = "butterfly-2",
    k_part: int | None = None,
    name: str = "",
) -> SimResult:
    """Slice-accurate simulation: tile -> schedule -> metrics."""
    arr = accel.array
    graph = tile_workload(gemms, arr, k_part=k_part, num_banks=accel.num_pods)
    sched = SliceScheduler(
        num_pods=accel.num_pods,
        array_rows=arr.rows,
        pipeline_latency=arr.pipeline_latency,
        interconnect=interconnect,
    ).schedule(graph)

    k_bar = (sum(op.k for op in graph.ops) / len(graph.ops)) if graph.ops else arr.rows
    slice_cyc = _slice_cycles(accel, interconnect, k_bar)
    total_cycles = sched.num_slices * slice_cyc
    total_macs = graph.total_macs
    util = total_macs / (accel.num_pods * arr.num_pe * total_cycles) if total_cycles else 0.0
    energy, power = _energy(accel, graph, interconnect, total_cycles)
    return SimResult(
        name=name,
        total_macs=total_macs,
        total_cycles=int(total_cycles),
        num_pods=accel.num_pods,
        utilization=util,
        busy_pods=sched.pods_busy_fraction(),
        cycles_per_tile=slice_cyc,
        effective_tops_at_tdp=accel.peak_ops_at_tdp * util / 1e12,
        peak_tops_at_tdp=accel.peak_ops_at_tdp / 1e12,
        energy_joules=energy,
        avg_power_watts=power,
        num_tile_ops=len(graph.ops),
        num_slices=sched.num_slices,
    )


# ---------------------------------------------------------------------------
# analytical wave model (fast path for the Fig-5 DSE sweeps)
# ---------------------------------------------------------------------------

# relative pod-availability per fabric (Table 1 busy-pods, normalized to the
# full-permutation fabrics); only Butterfly-1's limited combinatorial power
# costs throughput.
_ICN_EFFICIENCY = {
    "butterfly-1": 66.81 / 72.41,
    "butterfly-2": 1.0, "butterfly-4": 1.0, "butterfly-8": 1.0,
    "crossbar": 1.0, "benes": 1.0, "mesh": 0.55, "htree": 0.45,
}


def _levels(gemms: list[GemmSpec]) -> list[list[GemmSpec]]:
    """Group layers into topological levels (parallel branches share one)."""
    depth: dict[int, int] = {}
    by_id = {g.gemm_id: g for g in gemms}
    order = sorted(gemms, key=lambda g: g.gemm_id)
    for g in order:
        d = 0
        for pid in g.depends_on:
            if pid in depth:
                d = max(d, depth[pid] + 1)
        depth[g.gemm_id] = d
    lv: dict[int, list[GemmSpec]] = defaultdict(list)
    for g in order:
        lv[depth[g.gemm_id]].append(g)
    return [lv[i] for i in sorted(lv)]


def analyze(
    gemms: list[GemmSpec],
    accel: AcceleratorConfig,
    interconnect: str = "butterfly-2",
    k_part: int | None = None,
    name: str = "",
) -> SimResult:
    """Closed-form wave model of the tiled schedule.

    Per level: every GEMM contributes ceil(d1/k)*ceil(d3/c) independent
    psum chains of length ceil(d2/r). Chains from all GEMMs of the level
    run concurrently in waves of `pods` (scaled by the fabric's busy-pod
    efficiency); the level cannot finish faster than its longest chain.
    """
    arr = accel.array
    r, c = arr.rows, arr.cols
    kp = k_part if k_part is not None else r
    eff_pods = accel.num_pods * _ICN_EFFICIENCY.get(interconnect, 1.0)

    total_macs = 0
    total_slices = 0.0
    total_tiles = 0
    k_sum = 0.0
    for level in _levels(gemms):
        pod_slices = 0.0
        crit = 0.0
        for g in level:
            kpg = max(1, min(kp, g.d1))
            n_i = math.ceil(g.d1 / kpg)
            n_j = math.ceil(g.d2 / r)
            n_l = math.ceil(g.d3 / c)
            pod_slices += n_i * n_j * n_l
            crit = max(crit, n_j)
            total_macs += g.macs
            total_tiles += n_i * n_j * n_l
            k_sum += n_i * n_j * n_l * (g.d1 / n_i)
        total_slices += max(crit, pod_slices / eff_pods)

    k_bar = (k_sum / total_tiles) if total_tiles else r
    slice_cyc = _slice_cycles(accel, interconnect, k_bar)
    total_cycles = total_slices * slice_cyc
    util = total_macs / (accel.num_pods * arr.num_pe * total_cycles) if total_cycles else 0.0
    busy = total_tiles / (total_slices * accel.num_pods) if total_slices else 0.0

    # energy: same accounting as the slice-accurate path without scheduling
    spec = icn_spec_for(interconnect, max(2, accel.num_pods))
    e_pj = 0.0
    for g in gemms:
        kpg = max(1, min(kp, g.d1))
        n_j = math.ceil(g.d2 / r)
        e_pj += g.macs * E_MAC_PJ
        e_pj += g.d1 * g.d2 * ACT_BYTES * (E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)
        e_pj += g.d2 * g.d3 * WEIGHT_BYTES * (E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)
        e_pj += g.d1 * g.d3 * PSUM_BYTES * (2 * n_j - 1) * (
            E_SRAM_PJ_PER_BYTE + spec.mw_per_byte)
    energy = e_pj * 1e-12
    t = total_cycles / arr.clock_hz if total_cycles else 0.0
    power = energy / t if t > 0 else 0.0

    return SimResult(
        name=name,
        total_macs=total_macs,
        total_cycles=int(total_cycles),
        num_pods=accel.num_pods,
        utilization=util,
        busy_pods=min(1.0, busy),
        cycles_per_tile=slice_cyc,
        effective_tops_at_tdp=accel.peak_ops_at_tdp * util / 1e12,
        peak_tops_at_tdp=accel.peak_ops_at_tdp / 1e12,
        energy_joules=energy,
        avg_power_watts=power,
        num_tile_ops=total_tiles,
        num_slices=int(total_slices),
    )


def merge_workloads(*workloads: list[GemmSpec]) -> list[GemmSpec]:
    """Multi-tenancy (§6.1): co-schedule independent workloads. GEMM ids are
    re-based so streams stay dependency-disjoint and interleave freely."""
    merged: list[GemmSpec] = []
    base = 0
    for wl in workloads:
        for g in wl:
            merged.append(GemmSpec(
                d1=g.d1, d2=g.d2, d3=g.d3,
                gemm_id=g.gemm_id + base,
                depends_on=tuple(d + base for d in g.depends_on),
                name=g.name,
            ))
        base += (max((g.gemm_id for g in wl), default=0) + 1)
    return merged
