"""SOSA data tiling (§3.3): GEMM -> tile-operation DAG.

A GEMM  X (d1 x d2) @ W (d2 x d3) (+ P_in)  on weight-stationary r x c pods
is partitioned as:

  * W into (r x c) tiles  — forced by the spatial layout,
  * X's second dim into r — forced by the contraction,
  * X's first dim into chunks of `k_part` — the paper's free parameter.

The paper's contribution is k_part = r: the smallest partition that does not
expose the r-cycle weight-buffering time (double buffering), maximizing the
number of *independent* tile ops:  n_parallel = ceil(d1/r) * ceil(d3/c).
Tiles along d2 (the contraction) form read-after-write chains through the
partial-sum input (or pairwise aggregation on post-processors, §4.2).

`tile_gemm` returns a TileOpGraph whose ops carry everything the scheduler
(core/scheduler.py), the simulator (core/simulator.py) and the numerical
executor (core/executor.py) need.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Iterator

import numpy as np

from .arrays import ArrayConfig


@dataclasses.dataclass(frozen=True)
class TileOp:
    """One (k x r') @ (r' x c') multiply-accumulate tile operation."""

    op_id: int
    gemm_id: int
    # tile indices within the GEMM: X row-chunk i, contraction chunk j,
    # W column-chunk l (paper Fig 8: x_ij @ w_jl (+ y_i,j-1,l) -> y_ijl).
    i: int
    j: int
    l: int
    # effective (edge-clipped) tile dims
    k: int       # rows of the X chunk streamed through the array
    r_eff: int   # contraction size  (<= array rows)
    c_eff: int   # output columns    (<= array cols)
    depends_on: tuple[int, ...] = ()   # op_ids (RAW: psum chain, inter-GEMM)
    # memory placement (bank ids are assigned by the tiler round-robin —
    # the paper stores X/W/P tiles in dedicated bank groups, Fig 7)
    x_bank: int = 0
    w_bank: int = 0
    p_bank: int = 0
    is_aggregation: bool = False  # post-processor pair-aggregation op

    @property
    def macs(self) -> int:
        return self.k * self.r_eff * self.c_eff


@dataclasses.dataclass
class GemmSpec:
    """A GEMM extracted from a DNN layer (after conv-to-GEMM conversion)."""

    d1: int                     # filter reuse   (X rows)
    d2: int                     # features       (contraction)
    d3: int                     # filters        (W cols)
    gemm_id: int = 0
    depends_on: tuple[int, ...] = ()   # gemm_ids of producer layers
    name: str = ""

    @property
    def macs(self) -> int:
        return self.d1 * self.d2 * self.d3


@dataclasses.dataclass
class TileOpGraph:
    ops: list[TileOp]
    num_banks: int
    # per-GEMM output tile ids: (gemm_id, i, l) -> op_id producing the final
    # accumulated output tile (end of the psum chain)
    final_tiles: dict[tuple[int, int, int], int]

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def total_macs(self) -> int:
        return sum(op.macs for op in self.ops)

    def parallel_frontier(self) -> int:
        """Number of ops with no intra-graph dependencies (available at t=0)."""
        return sum(1 for op in self.ops if not op.depends_on)


def _chunks(total: int, size: int) -> list[int]:
    """Chunk sizes covering `total` in steps of `size` (last may be short)."""
    if total <= 0:
        return []
    n = math.ceil(total / size)
    out = [size] * n
    out[-1] = total - size * (n - 1)
    return out


def tile_gemm(
    gemm: GemmSpec,
    array: ArrayConfig,
    k_part: int | None = None,
    num_banks: int = 256,
    start_op_id: int = 0,
    producer_final: dict[tuple[int, int, int], int] | None = None,
    producer_gemms: tuple[int, ...] = (),
    producer_all_ops: tuple[int, ...] = (),
    faulty_banks: tuple[int, ...] = (),
) -> TileOpGraph:
    """Tile one GEMM into TileOps (k_part=None -> the paper's r x r rule).

    producer_all_ops: op_ids this GEMM's first-wave tiles must wait for
    (coarse inter-layer dependency — the paper schedules layer by layer with
    RAW dependencies between them).

    faulty_banks: bank ids masked out of the round-robin placement (a dead
    pod takes its local SRAM bank group with it — degraded-pod retiling
    spreads tiles over the survivors). Empty mask reproduces the seed
    placement bit-for-bit.
    """
    r, c = array.rows, array.cols
    if k_part is None:
        k_part = r                       # the paper's optimal partition
    k_part = max(1, min(k_part, gemm.d1))

    k_chunks = _chunks(gemm.d1, k_part)
    r_chunks = _chunks(gemm.d2, r)
    c_chunks = _chunks(gemm.d3, c)

    ops: list[TileOp] = []
    final: dict[tuple[int, int, int], int] = {}
    oid = start_op_id

    # Bank placement: X tiles keyed by (i, j), W by (j, l), P by (i, l);
    # spread round-robin over the HEALTHY banks (single-ported, one reader
    # per slice). With no faulty banks, `banks[e % num_banks] == e %
    # num_banks` — identical to the seed placement.
    dead = set(faulty_banks)
    if any(b < 0 or b >= num_banks for b in dead):
        raise ValueError(f"faulty_banks {sorted(dead)} out of range "
                         f"for {num_banks} banks")
    banks = [b for b in range(num_banks) if b not in dead]
    if not banks:
        raise ValueError("all banks faulty: nothing to tile onto")

    def xb(i: int, j: int) -> int:
        return banks[(i * len(r_chunks) + j) % len(banks)]

    def wb(j: int, l: int) -> int:
        return banks[(gemm.gemm_id * 7 + j * len(c_chunks) + l) % len(banks)]

    def pb(i: int, l: int) -> int:
        return banks[(gemm.gemm_id * 13 + i * len(c_chunks) + l) % len(banks)]

    for i, k in enumerate(k_chunks):
        for l, c_eff in enumerate(c_chunks):
            prev: int | None = None
            for j, r_eff in enumerate(r_chunks):
                deps: list[int] = []
                if prev is not None:
                    deps.append(prev)          # psum chain along contraction
                if j == 0 and producer_all_ops:
                    deps.extend(producer_all_ops)
                ops.append(
                    TileOp(
                        op_id=oid, gemm_id=gemm.gemm_id,
                        i=i, j=j, l=l, k=k, r_eff=r_eff, c_eff=c_eff,
                        depends_on=tuple(deps),
                        x_bank=xb(i, j), w_bank=wb(j, l), p_bank=pb(i, l),
                    )
                )
                prev = oid
                oid += 1
            final[(gemm.gemm_id, i, l)] = prev  # last op in the chain
    return TileOpGraph(ops=ops, num_banks=num_banks, final_tiles=final)


def tile_workload(
    gemms: list[GemmSpec],
    array: ArrayConfig,
    k_part: int | None = None,
    num_banks: int = 256,
    layer_dependencies: bool = True,
    faulty_banks: tuple[int, ...] = (),
) -> TileOpGraph:
    """Tile a whole workload (list of GEMM layers, in execution order).

    When `layer_dependencies` is True, a layer's tiles depend on *all* tiles
    of the layers named in its `depends_on` (coarse RAW through activations;
    matches the paper's layer-by-layer scheduling). Tiles of layers with no
    producer/consumer relation (e.g. parallel branches, multi-tenant
    workloads) remain independent and interleave freely — the source of the
    paper's multi-tenancy gain (§6.1, Fig 11).
    """
    all_ops: list[TileOp] = []
    final: dict[tuple[int, int, int], int] = {}
    last_ops_of_gemm: dict[int, tuple[int, ...]] = {}
    oid = 0
    for gemm in gemms:
        producers: tuple[int, ...] = ()
        if layer_dependencies and gemm.depends_on:
            prod: list[int] = []
            for gid in gemm.depends_on:
                prod.extend(last_ops_of_gemm.get(gid, ()))
            producers = tuple(prod)
        g = tile_gemm(
            gemm, array, k_part=k_part, num_banks=num_banks,
            start_op_id=oid, producer_all_ops=producers,
            faulty_banks=faulty_banks,
        )
        all_ops.extend(g.ops)
        final.update(g.final_tiles)
        # consumers only need the *final* (fully accumulated) tiles
        last_ops_of_gemm[gemm.gemm_id] = tuple(
            opid for (gid, _, _), opid in g.final_tiles.items()
            if gid == gemm.gemm_id
        )
        oid += len(g.ops)
    return TileOpGraph(ops=all_ops, num_banks=num_banks, final_tiles=final)


# ---------------------------------------------------------------------------
# tile statistics fast path (no TileOp materialization)
# ---------------------------------------------------------------------------
#
# The DSE sweeps only need *counts* out of the tiling — how many tile ops a
# GEMM produces, the RAW-chain depth along the contraction, and the mean
# streamed activation rows k̄ — all of which are closed-form in (d1, d2, d3)
# and the array shape. `tile_stats` computes them as NumPy arrays over a
# whole workload at once; `gemm_levels` gives the topological level of each
# GEMM (parallel branches share a level), which is the schedule's outer
# barrier structure in the analytical wave model (simulator.analyze).


def gemm_levels(gemms: list[GemmSpec]) -> np.ndarray:
    """Topological level per GEMM, aligned with `gemms` order.

    Same rule as the offline scheduler's layer-by-layer barriers: a GEMM
    sits one level past its deepest producer; GEMMs with no producer/consumer
    relation (parallel branches, multi-tenant streams) share a level.
    Producers are resolved in gemm_id order; dangling ids are ignored.
    """
    depth: dict[int, int] = {}
    for g in sorted(gemms, key=lambda g: g.gemm_id):
        d = 0
        for pid in g.depends_on:
            if pid in depth:
                d = max(d, depth[pid] + 1)
        depth[g.gemm_id] = d
    return np.array([depth[g.gemm_id] for g in gemms], dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class TileStats:
    """Per-GEMM tile counts for one workload on one array shape.

    All fields are int64/float64 arrays of length len(gemms), in the same
    order as the input workload.
    """

    d1: np.ndarray
    d2: np.ndarray
    d3: np.ndarray
    macs: np.ndarray      # d1*d2*d3
    level: np.ndarray     # topological level (gemm_levels)
    n_i: np.ndarray       # activation chunks  ceil(d1/k_part)
    n_j: np.ndarray       # RAW psum-chain depth  ceil(d2/rows)
    n_l: np.ndarray       # weight column chunks  ceil(d3/cols)
    tiles: np.ndarray     # n_i * n_j * n_l
    k_eff: np.ndarray     # mean streamed rows per tile of this GEMM, d1/n_i

    @property
    def total_tiles(self) -> int:
        return int(self.tiles.sum())

    @property
    def total_macs(self) -> int:
        return int(self.macs.sum())

    @property
    def k_bar(self) -> float:
        """Tile-weighted mean activation rows streamed per tile op."""
        t = self.tiles.sum()
        return float((self.tiles * self.k_eff).sum() / t) if t else 0.0

    @property
    def max_chain(self) -> int:
        """Longest RAW psum chain in the workload (critical path, tiles)."""
        return int(self.n_j.max()) if len(self.n_j) else 0

    @property
    def parallel_frontier(self) -> int:
        """Tile ops with no intra-workload dependency available at t=0
        (first-level GEMMs' first chain links): sum of n_i*n_l there."""
        if not len(self.level):
            return 0
        first = self.level == self.level.min()
        return int((self.n_i[first] * self.n_l[first]).sum())


def tile_counts(d1, d2, d3, rows, cols, k_part=None):
    """`tile_gemm`'s chunk counts (n_i, n_j, n_l) as a broadcast-friendly
    closed form: same k_part clipping (the paper's r x r rule when k_part
    is None), same ceil divisions. All args may be NumPy arrays of any
    mutually broadcastable shapes — the single source of the formula for
    both `tile_stats` and the batched engine (simulator.analyze_batch)."""
    kp = rows if k_part is None else k_part
    kpg = np.maximum(1, np.minimum(kp, d1))
    n_i = -(-d1 // kpg)
    n_j = -(-d2 // rows)
    n_l = -(-d3 // cols)
    return n_i, n_j, n_l


def tile_stats(
    gemms: list[GemmSpec],
    array: ArrayConfig,
    k_part: int | None = None,
) -> TileStats:
    """Closed-form tile counts for `tile_gemm`'s partitioning, vectorized
    over a workload — verified property-based against the materializing
    tiler in tests/test_dse_batch.py.
    """
    d1 = np.array([g.d1 for g in gemms], dtype=np.int64)
    d2 = np.array([g.d2 for g in gemms], dtype=np.int64)
    d3 = np.array([g.d3 for g in gemms], dtype=np.int64)
    n_i, n_j, n_l = tile_counts(d1, d2, d3, array.rows, array.cols, k_part)
    return TileStats(
        d1=d1, d2=d2, d3=d3,
        macs=d1 * d2 * d3,
        level=gemm_levels(gemms),
        n_i=n_i, n_j=n_j, n_l=n_l,
        tiles=n_i * n_j * n_l,
        k_eff=d1 / n_i,
    )
