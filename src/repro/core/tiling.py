"""SOSA data tiling (§3.3): GEMM -> tile-operation DAG.

A GEMM  X (d1 x d2) @ W (d2 x d3) (+ P_in)  on weight-stationary r x c pods
is partitioned as:

  * W into (r x c) tiles  — forced by the spatial layout,
  * X's second dim into r — forced by the contraction,
  * X's first dim into chunks of `k_part` — the paper's free parameter.

The paper's contribution is k_part = r: the smallest partition that does not
expose the r-cycle weight-buffering time (double buffering), maximizing the
number of *independent* tile ops:  n_parallel = ceil(d1/r) * ceil(d3/c).
Tiles along d2 (the contraction) form read-after-write chains through the
partial-sum input (or pairwise aggregation on post-processors, §4.2).

`tile_gemm` returns a TileOpGraph whose ops carry everything the scheduler
(core/scheduler.py), the simulator (core/simulator.py) and the numerical
executor (core/executor.py) need.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

from .arrays import ArrayConfig


@dataclasses.dataclass(frozen=True)
class TileOp:
    """One (k x r') @ (r' x c') multiply-accumulate tile operation."""

    op_id: int
    gemm_id: int
    # tile indices within the GEMM: X row-chunk i, contraction chunk j,
    # W column-chunk l (paper Fig 8: x_ij @ w_jl (+ y_i,j-1,l) -> y_ijl).
    i: int
    j: int
    l: int
    # effective (edge-clipped) tile dims
    k: int       # rows of the X chunk streamed through the array
    r_eff: int   # contraction size  (<= array rows)
    c_eff: int   # output columns    (<= array cols)
    depends_on: tuple[int, ...] = ()   # op_ids (RAW: psum chain, inter-GEMM)
    # memory placement (bank ids are assigned by the tiler round-robin —
    # the paper stores X/W/P tiles in dedicated bank groups, Fig 7)
    x_bank: int = 0
    w_bank: int = 0
    p_bank: int = 0
    is_aggregation: bool = False  # post-processor pair-aggregation op

    @property
    def macs(self) -> int:
        return self.k * self.r_eff * self.c_eff


@dataclasses.dataclass
class GemmSpec:
    """A GEMM extracted from a DNN layer (after conv-to-GEMM conversion)."""

    d1: int                     # filter reuse   (X rows)
    d2: int                     # features       (contraction)
    d3: int                     # filters        (W cols)
    gemm_id: int = 0
    depends_on: tuple[int, ...] = ()   # gemm_ids of producer layers
    name: str = ""

    @property
    def macs(self) -> int:
        return self.d1 * self.d2 * self.d3


@dataclasses.dataclass
class TileOpGraph:
    ops: list[TileOp]
    num_banks: int
    # per-GEMM output tile ids: (gemm_id, i, l) -> op_id producing the final
    # accumulated output tile (end of the psum chain)
    final_tiles: dict[tuple[int, int, int], int]

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def total_macs(self) -> int:
        return sum(op.macs for op in self.ops)

    def parallel_frontier(self) -> int:
        """Number of ops with no intra-graph dependencies (available at t=0)."""
        return sum(1 for op in self.ops if not op.depends_on)


def _chunks(total: int, size: int) -> list[int]:
    """Chunk sizes covering `total` in steps of `size` (last may be short)."""
    if total <= 0:
        return []
    n = math.ceil(total / size)
    out = [size] * n
    out[-1] = total - size * (n - 1)
    return out


def tile_gemm(
    gemm: GemmSpec,
    array: ArrayConfig,
    k_part: int | None = None,
    num_banks: int = 256,
    start_op_id: int = 0,
    producer_final: dict[tuple[int, int, int], int] | None = None,
    producer_gemms: tuple[int, ...] = (),
    producer_all_ops: tuple[int, ...] = (),
) -> TileOpGraph:
    """Tile one GEMM into TileOps (k_part=None -> the paper's r x r rule).

    producer_all_ops: op_ids this GEMM's first-wave tiles must wait for
    (coarse inter-layer dependency — the paper schedules layer by layer with
    RAW dependencies between them).
    """
    r, c = array.rows, array.cols
    if k_part is None:
        k_part = r                       # the paper's optimal partition
    k_part = max(1, min(k_part, gemm.d1))

    k_chunks = _chunks(gemm.d1, k_part)
    r_chunks = _chunks(gemm.d2, r)
    c_chunks = _chunks(gemm.d3, c)

    ops: list[TileOp] = []
    final: dict[tuple[int, int, int], int] = {}
    oid = start_op_id

    # Bank placement: X tiles keyed by (i, j), W by (j, l), P by (i, l);
    # spread round-robin over banks (single-ported, one reader per slice).
    def xb(i: int, j: int) -> int:
        return (i * len(r_chunks) + j) % num_banks

    def wb(j: int, l: int) -> int:
        return (gemm.gemm_id * 7 + j * len(c_chunks) + l) % num_banks

    def pb(i: int, l: int) -> int:
        return (gemm.gemm_id * 13 + i * len(c_chunks) + l) % num_banks

    for i, k in enumerate(k_chunks):
        for l, c_eff in enumerate(c_chunks):
            prev: int | None = None
            for j, r_eff in enumerate(r_chunks):
                deps: list[int] = []
                if prev is not None:
                    deps.append(prev)          # psum chain along contraction
                if j == 0 and producer_all_ops:
                    deps.extend(producer_all_ops)
                ops.append(
                    TileOp(
                        op_id=oid, gemm_id=gemm.gemm_id,
                        i=i, j=j, l=l, k=k, r_eff=r_eff, c_eff=c_eff,
                        depends_on=tuple(deps),
                        x_bank=xb(i, j), w_bank=wb(j, l), p_bank=pb(i, l),
                    )
                )
                prev = oid
                oid += 1
            final[(gemm.gemm_id, i, l)] = prev  # last op in the chain
    return TileOpGraph(ops=ops, num_banks=num_banks, final_tiles=final)


def tile_workload(
    gemms: list[GemmSpec],
    array: ArrayConfig,
    k_part: int | None = None,
    num_banks: int = 256,
    layer_dependencies: bool = True,
) -> TileOpGraph:
    """Tile a whole workload (list of GEMM layers, in execution order).

    When `layer_dependencies` is True, a layer's tiles depend on *all* tiles
    of the layers named in its `depends_on` (coarse RAW through activations;
    matches the paper's layer-by-layer scheduling). Tiles of layers with no
    producer/consumer relation (e.g. parallel branches, multi-tenant
    workloads) remain independent and interleave freely — the source of the
    paper's multi-tenancy gain (§6.1, Fig 11).
    """
    all_ops: list[TileOp] = []
    final: dict[tuple[int, int, int], int] = {}
    last_ops_of_gemm: dict[int, tuple[int, ...]] = {}
    oid = 0
    for gemm in gemms:
        producers: tuple[int, ...] = ()
        if layer_dependencies and gemm.depends_on:
            prod: list[int] = []
            for gid in gemm.depends_on:
                prod.extend(last_ops_of_gemm.get(gid, ()))
            producers = tuple(prod)
        g = tile_gemm(
            gemm, array, k_part=k_part, num_banks=num_banks,
            start_op_id=oid, producer_all_ops=producers,
        )
        all_ops.extend(g.ops)
        final.update(g.final_tiles)
        # consumers only need the *final* (fully accumulated) tiles
        last_ops_of_gemm[gemm.gemm_id] = tuple(
            opid for (gid, _, _), opid in g.final_tiles.items()
            if gid == gemm.gemm_id
        )
        oid += len(g.ops)
    return TileOpGraph(ops=all_ops, num_banks=num_banks, final_tiles=final)
