"""DNN workload traces as GEMM layer lists (SOSA §5 benchmarks).

Convolutions are lowered through the pods' CONV-to-GEMM converter (im2col,
§4.1):  d1 = H_out*W_out (filter reuse), d2 = C_in*kh*kw (features),
d3 = C_out (filters). Transformer layers contribute their projection /
FFN GEMMs (d1 = sequence length) and the per-head attention matmuls.

Parametric generators for the paper's benchmark suite — ResNet-50/101/152,
DenseNet-121/169/201, Inception-v3 (structurally faithful trace) and
BERT-mini/small/medium/base/large — plus generic traces for the assigned
LM architectures (used by parallel/autoshard.py to drive sharding choices).
"""

from __future__ import annotations

import math

from .tiling import GemmSpec


class _Trace:
    """Builds a GemmSpec list with sequential or explicit dependencies."""

    def __init__(self):
        self.gemms: list[GemmSpec] = []
        self._next = 0

    def add(self, d1: int, d2: int, d3: int, deps: tuple[int, ...] | None = None,
            name: str = "") -> int:
        gid = self._next
        if deps is None:
            deps = (gid - 1,) if gid > 0 else ()
        self.gemms.append(GemmSpec(
            d1=max(1, int(d1)), d2=max(1, int(d2)), d3=max(1, int(d3)),
            gemm_id=gid, depends_on=tuple(d for d in deps if d >= 0), name=name))
        self._next += 1
        return gid


def _conv_out(hw: int, k: int, stride: int, pad: str = "same") -> int:
    if pad == "same":
        return math.ceil(hw / stride)
    return (hw - k) // stride + 1


def _conv(t: _Trace, hw: int, cin: int, cout: int, k: int, stride: int = 1,
          deps=None, name="conv", batch: int = 1) -> tuple[int, int]:
    out = _conv_out(hw, k, stride)
    gid = t.add(batch * out * out, cin * k * k, cout, deps=deps, name=name)
    return gid, out


def resnet(depth: int = 50, image: int = 224, batch: int = 1) -> list[GemmSpec]:
    """ResNet-50/101/152 bottleneck trace."""
    blocks = {50: (3, 4, 6, 3), 101: (3, 4, 23, 3), 152: (3, 8, 36, 3)}[depth]
    t = _Trace()
    _, hw = _conv(t, image, 3, 64, 7, 2, name="stem", batch=batch)
    hw = _conv_out(hw, 3, 2)  # maxpool
    cin = 64
    width = 64
    for stage, n in enumerate(blocks):
        stride = 1 if stage == 0 else 2
        for b in range(n):
            s = stride if b == 0 else 1
            prev = t._next - 1
            g1, hw1 = _conv(t, hw, cin, width, 1, s, deps=(prev,), name="b1", batch=batch)
            g2, hw1 = _conv(t, hw1, width, width, 3, 1, deps=(g1,), name="b3", batch=batch)
            g3, hw1 = _conv(t, hw1, width, width * 4, 1, 1, deps=(g2,), name="b1x", batch=batch)
            if b == 0:  # projection shortcut — parallel branch
                _conv(t, hw, cin, width * 4, 1, s, deps=(prev,), name="proj", batch=batch)
            hw, cin = hw1, width * 4
        width *= 2
    t.add(batch, cin, 1000, name="fc")
    return t.gemms


def densenet(depth: int = 121, image: int = 224, batch: int = 1,
             growth: int = 32) -> list[GemmSpec]:
    blocks = {121: (6, 12, 24, 16), 169: (6, 12, 32, 32),
              201: (6, 12, 48, 32)}[depth]
    t = _Trace()
    _, hw = _conv(t, image, 3, 2 * growth, 7, 2, name="stem", batch=batch)
    hw = _conv_out(hw, 3, 2)
    cin = 2 * growth
    for stage, n in enumerate(blocks):
        for _ in range(n):
            prev = t._next - 1
            g1, _ = _conv(t, hw, cin, 4 * growth, 1, 1, deps=(prev,), name="d1", batch=batch)
            _conv(t, hw, 4 * growth, growth, 3, 1, deps=(g1,), name="d3", batch=batch)
            cin += growth
        if stage < len(blocks) - 1:
            prev = t._next - 1
            cin //= 2
            _, _ = _conv(t, hw, cin * 2, cin, 1, 1, deps=(prev,), name="trans", batch=batch)
            hw = _conv_out(hw, 2, 2)
    t.add(batch, cin, 1000, name="fc")
    return t.gemms


def inception_v3(image: int = 299, batch: int = 1) -> list[GemmSpec]:
    """Structurally faithful Inception-v3 trace: stem + 11 inception blocks
    with parallel 1x1 / 3x3 / factorized-7x7 / pool-proj branches."""
    t = _Trace()
    _, hw = _conv(t, image, 3, 32, 3, 2, name="stem1", batch=batch)
    _, hw = _conv(t, hw, 32, 32, 3, 1, name="stem2", batch=batch)
    _, hw = _conv(t, hw, 32, 64, 3, 1, name="stem3", batch=batch)
    hw = _conv_out(hw, 3, 2)
    _, hw = _conv(t, hw, 64, 80, 1, 1, name="stem4", batch=batch)
    _, hw = _conv(t, hw, 80, 192, 3, 1, name="stem5", batch=batch)
    hw = _conv_out(hw, 3, 2)
    cin = 192

    def block_a(cin: int, pool_c: int) -> int:
        root = t._next - 1
        b1, _ = _conv(t, hw, cin, 64, 1, 1, deps=(root,), name="a1", batch=batch)
        b2a, _ = _conv(t, hw, cin, 48, 1, 1, deps=(root,), name="a5a", batch=batch)
        b2b, _ = _conv(t, hw, 48, 64, 5, 1, deps=(b2a,), name="a5b", batch=batch)
        b3a, _ = _conv(t, hw, cin, 64, 1, 1, deps=(root,), name="a3a", batch=batch)
        b3b, _ = _conv(t, hw, 64, 96, 3, 1, deps=(b3a,), name="a3b", batch=batch)
        b3c, _ = _conv(t, hw, 96, 96, 3, 1, deps=(b3b,), name="a3c", batch=batch)
        b4, _ = _conv(t, hw, cin, pool_c, 1, 1, deps=(root,), name="apool", batch=batch)
        return 64 + 64 + 96 + pool_c

    for pool_c in (32, 64, 64):
        cin = block_a(cin, pool_c)
    # reduction A
    root = t._next - 1
    _conv(t, hw, cin, 384, 3, 2, deps=(root,), name="ra1", batch=batch)
    g, _ = _conv(t, hw, cin, 64, 1, 1, deps=(root,), name="ra2a", batch=batch)
    g, _ = _conv(t, hw, 64, 96, 3, 1, deps=(g,), name="ra2b", batch=batch)
    _conv(t, hw, 96, 96, 3, 2, deps=(g,), name="ra2c", batch=batch)
    hw = _conv_out(hw, 3, 2)
    cin = 384 + 96 + cin  # + pooled passthrough

    def block_b(cin: int, f7: int) -> int:
        root = t._next - 1
        _conv(t, hw, cin, 192, 1, 1, deps=(root,), name="b1", batch=batch)
        g, _ = _conv(t, hw, cin, f7, 1, 1, deps=(root,), name="b7a", batch=batch)
        g, _ = t.add(batch * hw * hw, f7 * 7, f7, deps=(g,), name="b7b"), hw
        g2, _ = t.add(batch * hw * hw, f7 * 7, 192, deps=(g,), name="b7c"), hw
        g3, _ = _conv(t, hw, cin, f7, 1, 1, deps=(root,), name="b7d", batch=batch)
        g3, _ = t.add(batch * hw * hw, f7 * 7, f7, deps=(g3,), name="b7e"), hw
        g3, _ = t.add(batch * hw * hw, f7 * 7, f7, deps=(g3,), name="b7f"), hw
        g3, _ = t.add(batch * hw * hw, f7 * 7, f7, deps=(g3,), name="b7g"), hw
        g3, _ = t.add(batch * hw * hw, f7 * 7, 192, deps=(g3,), name="b7h"), hw
        _conv(t, hw, cin, 192, 1, 1, deps=(root,), name="bpool", batch=batch)
        return 192 * 4

    for f7 in (128, 160, 160, 192):
        cin = block_b(cin, f7)
    # reduction B
    root = t._next - 1
    g, _ = _conv(t, hw, cin, 192, 1, 1, deps=(root,), name="rb1a", batch=batch)
    _conv(t, hw, 192, 320, 3, 2, deps=(g,), name="rb1b", batch=batch)
    g, _ = _conv(t, hw, cin, 192, 1, 1, deps=(root,), name="rb2a", batch=batch)
    g = t.add(batch * hw * hw, 192 * 7, 192, deps=(g,), name="rb2b")
    g = t.add(batch * hw * hw, 192 * 7, 192, deps=(g,), name="rb2c")
    _conv(t, hw, 192, 192, 3, 2, deps=(g,), name="rb2d", batch=batch)
    hw = _conv_out(hw, 3, 2)
    cin = 320 + 192 + cin

    def block_c(cin: int) -> int:
        root = t._next - 1
        _conv(t, hw, cin, 320, 1, 1, deps=(root,), name="c1", batch=batch)
        g, _ = _conv(t, hw, cin, 384, 1, 1, deps=(root,), name="c3a", batch=batch)
        t.add(batch * hw * hw, 384 * 3, 384, deps=(g,), name="c3b")
        t.add(batch * hw * hw, 384 * 3, 384, deps=(g,), name="c3c")
        g, _ = _conv(t, hw, cin, 448, 1, 1, deps=(root,), name="c5a", batch=batch)
        g2, _ = _conv(t, hw, 448, 384, 3, 1, deps=(g,), name="c5b", batch=batch)
        t.add(batch * hw * hw, 384 * 3, 384, deps=(g2,), name="c5c")
        t.add(batch * hw * hw, 384 * 3, 384, deps=(g2,), name="c5d")
        _conv(t, hw, cin, 192, 1, 1, deps=(root,), name="cpool", batch=batch)
        return 320 + 768 + 768 + 192

    for _ in range(2):
        cin = block_c(cin)
    t.add(batch, cin, 1000, name="fc")
    return t.gemms


_BERT_SIZES = {
    "mini": (4, 256, 4), "small": (4, 512, 8), "medium": (8, 512, 8),
    "base": (12, 768, 12), "large": (24, 1024, 16),
}


def bert(size: str = "base", seq: int = 100, batch: int = 1,
         include_attention: bool = True) -> list[GemmSpec]:
    layers, h, heads = _BERT_SIZES[size]
    t = _Trace()
    s = seq * batch
    hd = h // heads
    for _ in range(layers):
        prev = t._next - 1
        q = t.add(s, h, h, deps=(prev,), name="q")
        k = t.add(s, h, h, deps=(prev,), name="k")
        v = t.add(s, h, h, deps=(prev,), name="v")
        last = (q, k, v)
        if include_attention:
            scores = [t.add(seq, hd, seq, deps=(q, k), name="qk")
                      for _ in range(heads * batch)]
            ctx = [t.add(seq, seq, hd, deps=(sc, v), name="av")
                   for sc in scores]
            last = tuple(ctx)
        o = t.add(s, h, h, deps=last, name="o")
        f1 = t.add(s, h, 4 * h, deps=(o,), name="ffn1")
        t.add(s, 4 * h, h, deps=(f1,), name="ffn2")
    return t.gemms


def transformer_lm(n_layers: int, d_model: int, n_heads: int, d_ff: int,
                   seq: int, batch: int = 1, vocab: int = 0,
                   n_kv_heads: int | None = None,
                   include_attention: bool = True) -> list[GemmSpec]:
    """Generic decoder-LM weight-GEMM trace (for assigned-arch analysis)."""
    t = _Trace()
    s = seq * batch
    kv = n_kv_heads or n_heads
    hd = d_model // n_heads
    for _ in range(n_layers):
        prev = t._next - 1
        q = t.add(s, d_model, n_heads * hd, deps=(prev,), name="q")
        k = t.add(s, d_model, kv * hd, deps=(prev,), name="k")
        v = t.add(s, d_model, kv * hd, deps=(prev,), name="v")
        last = (q, k, v)
        if include_attention:
            sc = t.add(seq, hd, seq, deps=(q, k), name="qk")
            av = t.add(seq, seq, hd, deps=(sc, v), name="av")
            last = (av,)
        o = t.add(s, n_heads * hd, d_model, deps=last, name="o")
        f1 = t.add(s, d_model, d_ff, deps=(o,), name="ffn_up")
        g1 = t.add(s, d_model, d_ff, deps=(o,), name="ffn_gate")
        t.add(s, d_ff, d_model, deps=(f1, g1), name="ffn_down")
    if vocab:
        t.add(s, d_model, vocab, name="lm_head")
    return t.gemms


# -- the paper's benchmark suites (§5) --------------------------------------

def cnn_suite(batch: int = 1, image: int = 299) -> dict[str, list[GemmSpec]]:
    return {
        "inception-v3": inception_v3(image, batch),
        "resnet50": resnet(50, image, batch),
        "resnet101": resnet(101, image, batch),
        "resnet152": resnet(152, image, batch),
        "densenet121": densenet(121, image, batch),
        "densenet169": densenet(169, image, batch),
        "densenet201": densenet(201, image, batch),
    }


def bert_suite(seq: int = 100, batch: int = 1) -> dict[str, list[GemmSpec]]:
    return {
        "bert-medium": bert("medium", seq, batch),
        "bert-base": bert("base", seq, batch),
        "bert-large": bert("large", seq, batch),
    }


def full_suite(batch: int = 1) -> dict[str, list[GemmSpec]]:
    out = cnn_suite(batch)
    out.update(bert_suite(100, batch))
    return out


def dse_cnn_suite() -> dict[str, list[GemmSpec]]:
    """Fig 5a workloads: CNNs at 224/256/299 (one representative each)."""
    out = {}
    for img in (224, 256, 299):
        out[f"resnet50@{img}"] = resnet(50, img)
        out[f"densenet121@{img}"] = densenet(121, img)
        out[f"inception@{img}"] = inception_v3(img)
    return out


def dse_transformer_suite() -> dict[str, list[GemmSpec]]:
    """Fig 5b workloads: BERT mini..large x sequence lengths [57]."""
    out = {}
    for size in ("mini", "small", "medium", "base", "large"):
        for seq in (10, 40, 100, 300, 500):
            out[f"bert-{size}@{seq}"] = bert(size, seq)
    return out
