"""Pallas TPU kernel: blocked flash attention (GQA, causal, sliding window).

Grid (B, Hq, nq, nk), K-blocks minor: TPU executes the grid sequentially
minor-to-major, so f32 scratch (acc, m, l) carries the online softmax state
across K blocks of one Q block — the same psum-carrying pattern as the
systolic GEMM (and the paper's psum chaining, DESIGN.md §2).

Block sizes (bq x bk) are the attention-level output of the SOSA
granularity analysis: defaults 512x512 keep q/k/v/acc blocks ~0.75 MiB in
VMEM (bf16) — comfortably triple-bufferable — with MXU-aligned lane dims.

GQA is expressed in the index maps: K/V blocks are fetched for head
h // (Hq // Hkv); no repeat/materialization of KV heads ever happens.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  n_k: int, bq: int, bk: int, causal: bool,
                  window: int | None, scale: float, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :] * scale                    # [bq, D]
    k = k_ref[0, :, 0, :]                            # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < kv_len
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, :, 0, :],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,                  # [B, Sq, Hq, D]
    k: jax.Array,                  # [B, Skv, Hkv, D]
    v: jax.Array,                  # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softmax_scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    kv_len: int | None = None,     # unpadded KV length (mask tail)
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "ops.py pads to block multiples"
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    n_k = Skv // bk
    grid = (B, Hq, Sq // bq, n_k)

    kernel = functools.partial(
        _flash_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal, window=window,
        scale=scale, kv_len=kv_len if kv_len is not None else Skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik, g=g: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik, g=g: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
