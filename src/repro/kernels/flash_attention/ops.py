"""jit'd public wrapper: pads sequence dims to block multiples and masks
the padded KV tail via kv_len."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softmax_scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softmax_scale: float | None = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window,
        softmax_scale=softmax_scale, block_q=bq, block_k=bk,
        kv_len=Skv, interpret=interpret)
    return out[:, :Sq]
