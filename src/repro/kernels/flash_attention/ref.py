"""Pure-jnp oracle for flash attention (shared with models/attention)."""

from __future__ import annotations

from repro.models.attention import naive_attention


def flash_attention_ref(q, k, v, *, causal=True, window=None,
                        softmax_scale=None, kv_len=None):
    return naive_attention(q, k, v, causal=causal, window=window,
                           softmax_scale=softmax_scale, kv_valid_len=kv_len)
