"""jit'd public wrapper for the SSD kernel: broadcasts groups to heads,
pads S to a chunk multiple, returns (y, final_state)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd import ssd_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, D, *, chunk: int = 128, interpret: bool | None = None):
    """x [b,S,H,P]; dt [b,S,H]; A,D [H]; B,C [b,S,G,N] with G | H.
    Returns (y [b,S,H,P], h_final [b,H,P,N]).

    Note: h_final is recomputed with the jnp reference recurrence (cheap,
    O(S/C) chunk reductions) because the kernel's scratch state is not an
    output; serving paths that need the state use models/ssm directly.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, S, H, P = x.shape
    G = B.shape[2]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = ssd_pallas(x, dt, A, Bh, Ch, D, chunk=chunk, interpret=interpret)

    # final state via the chunk recurrence (matches ssd_reference)
    from repro.models.ssm import ssd_reference
    _, h_final = ssd_reference(x[:, :S], dt[:, :S], A, Bh[:, :S],
                               Ch[:, :S], D, chunk)
    return y[:, :S], h_final
