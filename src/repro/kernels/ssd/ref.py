"""Pure-jnp oracle for the SSD chunk kernel (models/ssm.ssd_reference)."""

from __future__ import annotations

from repro.models.ssm import ssd_reference


def ssd_ref(x, dt, A, B, C, D, *, chunk: int = 128):
    """Same contract as ops.ssd: B, C given per-head [b,S,H,N]."""
    # ssd_reference takes grouped B/C [b,S,G,N]; per-head input is G == H.
    return ssd_reference(x, dt, A, B, C, D, chunk)
