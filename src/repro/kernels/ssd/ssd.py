"""Pallas TPU kernel: Mamba-2 SSD chunk scan.

State-space duality makes the SSM computable as chunked GEMMs — exactly
the regime the paper's tiling targets (DESIGN.md §4): per (batch, head)
the sequence is cut into chunks of C tokens; within a chunk the output is
two small matmuls ([C,N]x[N,C] scores and [C,C]x[C,P] values), and a
[P,N] state carries across chunks through VMEM scratch (grid minor dim is
the chunk index — the same sequential-accumulator pattern as the systolic
GEMM kernel).

Tile shapes: C=chunk (default 128..256), N=d_state (128), P=head_dim (64)
— all MXU-friendly. The f32 state scratch is 32-128 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref,
                *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :]                         # [C, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # [C]
    A = a_ref[0].astype(jnp.float32)              # scalar (negative)
    B = b_ref[0, :, 0, :]                         # [C, N]
    C = c_ref[0, :, 0, :]                         # [C, N]
    D = d_ref[0].astype(jnp.float32)              # scalar

    dA = dt * A                                   # [C]
    cum = jnp.cumsum(dA)                          # [C]
    # intra-chunk: M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s <= t
    seg = cum[:, None] - cum[None, :]             # [C, C]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    M = scores * decay * dt[None, :]
    y = jax.lax.dot_general(
        M.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [C, P]

    # inter-chunk: y += exp(cum_t) * C_t . h_prev^T   (h [P, N])
    h_prev = h_ref[...]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C.astype(jnp.float32), h_prev.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h <- exp(cum_end) * h + sum_s exp(cum_end - cum_s) dt_s x_s B_s^T
    w = (jnp.exp(cum[-1] - cum) * dt)             # [C]
    h_new = jnp.exp(cum[-1]) * h_prev + jax.lax.dot_general(
        (x.astype(jnp.float32) * w[:, None]), B.astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_ref[...] = h_new.astype(h_ref.dtype)

    y_ref[0, :, 0, :] = (y + D * x.astype(jnp.float32)).astype(y_ref.dtype)


def ssd_pallas(x, dt, A, B, C, D, *, chunk: int = 128,
               interpret: bool = False):
    """x [b,S,H,P]; dt [b,S,H]; A,D [H]; B,C [b,S,H,N] (groups pre-broadcast
    by ops.py). Returns y [b,S,H,P]. S must be a chunk multiple (ops pads).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    grid = (b, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, h, c: (i, c, h)),
            pl.BlockSpec((1,), lambda i, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1,), lambda i, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda i, h, c: (i, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
