"""SDC safety for the pod-GEMM path: ABFT checksums + Freivalds probes.

At 256-pod scale a bit flip inside one systolic tile silently corrupts
one output element — and one wrong logit emits wrong tokens forever.
This module wraps the pallas pod GEMM in an algorithm-based fault
tolerance (ABFT) envelope:

  * **abft** — the classic Huang–Abraham scheme: append the column-sum
    row to A and the row-sum column to B, so ``C_aug = A_aug @ B_aug``
    carries a checksum row and column of C for free. Comparing them
    against the freshly summed data block *detects* corruption, and a
    single corrupted element is *located* at (argmax row residual,
    argmax col residual) — the faulty (block_m, block_n) tile follows
    from the autotuned geometry. The located element is repaired by an
    exact f32 recompute of that one dot product (cheaper and tighter
    than residual addition, whose checksum rounding noise would leak
    into the corrected value).
  * **probe** — a randomized Freivalds check: ``C @ v`` vs
    ``A @ (B @ v)`` for a Rademacher vector v. Detection only (no
    location), O(MN + MK + KN) instead of an extra GEMM column.
    A *single-element* corruption of magnitude above the float-noise
    tolerance is always detected (the residual at its row is exactly
    ``±delta``); an adversarial multi-element corruption pattern E
    escapes one probe only if ``E @ v = 0``, which for Rademacher v
    has probability <= 1/2 per probe, so <= 2**-probes overall — the
    documented bound the property test exercises.
  * **off** — the guard is never consulted; the serving path is
    bit-identical to the unguarded engine (tokens, jit cache sizes,
    host sync counts — gated by test).

Guarded execution runs the *raw* kernel (unit scale, zero bias, no
activation, f32 out — an identity epilogue, so the kernel's accumulator
is observed exactly), verifies/corrects, then applies the same
``_epilogue_math`` the fused kernel would have. The guarded path is
deliberately NOT wrapped in its own ``jax.jit``: the GuardTape below
has trace-time side effects (per-call GEMM indices, flag registration)
that an inner jit cache would silently skip on a cache hit. Inside the
engine's outer jit it is traced inline; in eager tests it runs per call.

Float tolerance: checksums are computed in f32 but stored in the input
dtype, so for bf16 the checksum row carries ~2**-9 relative rounding
noise against the f32-accumulated data sums. The default ``rtol`` of
1/64 sits ~8x above that noise floor and far below any corruption worth
detecting (an SDC bit flip in exponent or high mantissa moves the value
by orders of magnitude). int8 inputs are rejected under ``abft`` — an
int8 column sum overflows the int8 checksum row.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .systolic_gemm import _epilogue_math

OFF, PROBE, ABFT = "off", "probe", "abft"
MODES = (OFF, PROBE, ABFT)

# static unroll bound for injected corruptions per GEMM (2 distinct
# rows/cols defeats single-corruption ABFT location -> uncorrectable)
MAX_SDC_ELEMS = 2


@dataclasses.dataclass(frozen=True)
class PodGuard:
    """SDC-guard config for the pod-GEMM path.

    mode:   "off" (bit-identical to unguarded), "probe" (Freivalds,
            detect-only), "abft" (checksum row/col: detect + locate +
            correct single corruptions).
    rtol:   float-noise tolerance, relative to the largest augmented-
            output magnitude (see module docstring).
    probes: independent Freivalds probes; miss probability for an
            adversarial corruption is <= 2**-probes.
    probe_seed: PRNG seed for the Rademacher probe vectors.
    """

    mode: str = OFF
    rtol: float = 1.0 / 64.0
    probes: int = 1
    probe_seed: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"PodGuard.mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if not (0.0 < self.rtol < 1.0):
            raise ValueError(f"rtol must be in (0, 1), got {self.rtol}")
        if self.probes < 1:
            raise ValueError("probes must be >= 1")


def as_guard(guard) -> PodGuard:
    """None -> off; a mode string -> PodGuard(mode); PodGuard passes."""
    if guard is None:
        return PodGuard(mode=OFF)
    if isinstance(guard, str):
        return PodGuard(mode=guard)
    if isinstance(guard, PodGuard):
        return guard
    raise TypeError(f"guard must be None, str, or PodGuard, got "
                    f"{type(guard).__name__}")


# ---------------------------------------------------------------------------
# GuardTape: trace-time accumulator threading guard state through a model
# call without touching the Model API. layers.pod_dense/unembed consult
# active_guard(); the guarded GEMM registers its verdict flags on the
# tape; the engine returns tape.totals() as extra jit outputs so the
# verdicts ride the existing host syncs as runtime values.
# ---------------------------------------------------------------------------

_TAPES: list["GuardTape"] = []


class GuardTape:
    """Context manager scoping a PodGuard (and optional SDC injection
    plan) over every pod GEMM traced inside the ``with`` block.

    ``inject`` is a traced int32[3] ``(target_gemm, draw_seed, n_elems)``
    plan (or None): the guarded GEMM whose trace-order index equals
    ``target_gemm`` gets ``n_elems`` elements of its raw output
    corrupted by ``magnitude`` — a pure function of the plan, so the
    schedule is deterministic under jit. ``target_gemm < 0`` disarms.
    """

    def __init__(self, guard: PodGuard, inject=None,
                 magnitude: float = 1e4):
        self.guard = guard
        self.inject = inject
        self.magnitude = float(magnitude)
        self._next = 0
        self._corrected = []
        self._uncorrected = []

    def __enter__(self):
        _TAPES.append(self)
        return self

    def __exit__(self, *exc):
        popped = _TAPES.pop()
        assert popped is self, "unbalanced GuardTape nesting"
        return False

    def next_index(self) -> int:
        i = self._next
        self._next += 1
        return i

    def record(self, corrected, uncorrected) -> None:
        self._corrected.append(jnp.asarray(corrected, jnp.int32))
        self._uncorrected.append(jnp.asarray(uncorrected, jnp.int32))

    def totals(self):
        """(corrected_total, uncorrected_total) as traced int32 scalars."""
        zero = jnp.int32(0)
        corr = sum(self._corrected, zero)
        unc = sum(self._uncorrected, zero)
        return jnp.asarray(corr, jnp.int32), jnp.asarray(unc, jnp.int32)

    @property
    def gemms(self) -> int:
        """Guarded GEMMs registered so far (trace-time count)."""
        return self._next


def active_tape():
    return _TAPES[-1] if _TAPES else None


def active_guard():
    """The PodGuard of the innermost tape, or None (-> unguarded path)."""
    tape = active_tape()
    if tape is None or tape.guard.mode == OFF:
        return None
    return tape.guard


# ---------------------------------------------------------------------------
# ABFT math
# ---------------------------------------------------------------------------

def augment_x(x):
    """Append the column-sum checksum row: [M, K] -> [M+1, K]."""
    ck = x.astype(jnp.float32).sum(axis=0, keepdims=True).astype(x.dtype)
    return jnp.concatenate([x, ck], axis=0)


def augment_w(w):
    """Append the row-sum checksum column: [K, N] -> [K, N+1]."""
    ck = w.astype(jnp.float32).sum(axis=1, keepdims=True).astype(w.dtype)
    return jnp.concatenate([w, ck], axis=1)


def augment_wt(w):
    """Transposed-layout checksum: w [N, K] -> [N+1, K]; the appended row
    is the sum over N, so ``x_aug @ w_aug.T`` carries the same checksum
    column as the [K, N] layout would."""
    ck = w.astype(jnp.float32).sum(axis=0, keepdims=True).astype(w.dtype)
    return jnp.concatenate([w, ck], axis=0)


def _tol(c_aug, rtol: float):
    """Detection threshold: relative to the largest augmented magnitude
    (the checksum row/col dominates), so float accumulation noise stays
    under it while any corruption worth catching clears it — including
    when the corrupted element itself is what dominates the max."""
    return rtol * (jnp.max(jnp.abs(c_aug)) + 1.0)


def abft_verify(c_aug, x, w, *, rtol: float, transpose: bool = False):
    """Check (and repair) one raw augmented GEMM output.

    c_aug: [M+1, N+1] f32 raw kernel output of the augmented operands.
    x:     [M, K] original left operand.
    w:     [K, N] (or [N, K] when ``transpose``) original right operand.

    Returns ``(c, report)`` where c is the verified/corrected [M, N]
    data block and report holds traced int32 scalars:

      detected    any residual above tolerance
      corrected   corruption contained (single data element repaired by
                  exact recompute, or checksum-only hit — data clean)
      uncorrected detected but not provably repaired -> caller must
                  recompute (the engine retries the device call)
      row, col    located data element (argmax residuals; only
                  meaningful when a single data corruption was found)
    """
    M = x.shape[0]
    N = w.shape[0] if transpose else w.shape[1]
    c = c_aug[:M, :N]
    row_ck = c_aug[:M, N]                      # checksum column -> per-row
    col_ck = c_aug[M, :N]                      # checksum row    -> per-col
    row_res = row_ck - c.sum(axis=1)
    col_res = col_ck - c.sum(axis=0)
    tol = _tol(c_aug, rtol)
    row_bad = jnp.abs(row_res) > tol
    col_bad = jnp.abs(col_res) > tol
    n_row = row_bad.sum(dtype=jnp.int32)
    n_col = col_bad.sum(dtype=jnp.int32)
    detected = (n_row > 0) | (n_col > 0)
    # a data corruption at (r, cc) moves row_res[r] AND col_res[cc] by
    # the same -delta; a hit confined to the checksum row/col moves only
    # one side -> the data block is clean and the checksums are discarded
    checksum_only = (n_row > 0) != (n_col > 0)
    locatable = (n_row == 1) & (n_col == 1)
    r = jnp.argmax(jnp.abs(row_res)).astype(jnp.int32)
    cc = jnp.argmax(jnp.abs(col_res)).astype(jnp.int32)
    # repair by exact f32 recompute of the one located dot product —
    # residual addition would fold the checksum rounding noise into the
    # corrected value; a fresh dot is accurate to f32 accumulation order
    xr = jnp.take(x, r, axis=0).astype(jnp.float32)
    wc = (jnp.take(w, cc, axis=0) if transpose
          else jnp.take(w, cc, axis=1)).astype(jnp.float32)
    fix = jnp.dot(xr, wc)
    fixed = jnp.where(locatable, c.at[r, cc].set(fix), c)
    # recheck the repaired row/col: a multi-corruption masquerading as a
    # single one leaves a residual after the fix and stays uncorrected
    rr_after = jnp.abs(row_ck[r] - fixed[r, :].sum())
    cr_after = jnp.abs(col_ck[cc] - fixed[:, cc].sum())
    fix_ok = locatable & (rr_after <= tol) & (cr_after <= tol)
    c = jnp.where(fix_ok, fixed, c)
    corrected = (fix_ok | checksum_only) & detected
    uncorrected = detected & ~corrected
    report = {
        "detected": detected.astype(jnp.int32),
        "corrected": corrected.astype(jnp.int32),
        "uncorrected": uncorrected.astype(jnp.int32),
        "row": r,
        "col": cc,
    }
    return c, report


def freivalds_detect(c, x, w, *, probes: int, seed: int, rtol: float,
                     transpose: bool = False):
    """Randomized verification: ``C @ v`` vs ``A @ (B @ v)`` in f32 for
    ``probes`` independent Rademacher vectors. Returns a traced int32
    detection flag. Miss probability for an adversarial corruption is
    <= 2**-probes; a lone corrupted element above tolerance is always
    caught (its row residual is exactly +-delta)."""
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    N = c.shape[1]
    key = jax.random.PRNGKey(seed)
    detected = jnp.bool_(False)
    tol = _tol(c, rtol) * max(1, int(N)) ** 0.5  # residual sums ~sqrt(N)
    for p in range(probes):
        v = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, p),
                                           0.5, (N,)), 1.0, -1.0)
        bv = jnp.dot(v, wf) if transpose else jnp.dot(wf, v)
        resid = jnp.dot(c, v) - jnp.dot(xf, bv)
        detected = detected | (jnp.max(jnp.abs(resid)) > tol)
    return detected.astype(jnp.int32)


def tile_of(row, col, block_m: int, block_n: int):
    """Map a located element to its (block_m, block_n) output tile."""
    return row // block_m, col // block_n


# ---------------------------------------------------------------------------
# Deterministic kernel-level SDC injection (testing hook; serve/chaos.py
# draws the plan host-side, the corruption itself is traced)
# ---------------------------------------------------------------------------

def inject_sdc(c, gemm_index: int, plan, magnitude: float,
               data_m: int, data_n: int):
    """Corrupt the raw GEMM output per an int32[3] plan
    ``(target_gemm, draw_seed, n_elems)``. A no-op unless
    ``target_gemm == gemm_index``. Element e lands at
    ``((r0+e) % data_m, (c0+e) % data_n)`` with (r0, c0) drawn from
    ``draw_seed`` — successive elements occupy distinct rows AND
    columns (for data_m, data_n >= 2), so ``n_elems >= 2`` is
    guaranteed to defeat single-corruption ABFT location."""
    plan = jnp.asarray(plan, jnp.int32)
    hit = plan[0] == jnp.int32(gemm_index)
    kr, kc = jax.random.split(jax.random.PRNGKey(plan[1]))
    r0 = jax.random.randint(kr, (), 0, data_m)
    c0 = jax.random.randint(kc, (), 0, data_n)
    for e in range(MAX_SDC_ELEMS):
        amt = jnp.where(hit & (e < plan[2]), jnp.float32(magnitude),
                        jnp.float32(0.0))
        c = c.at[(r0 + e) % data_m, (c0 + e) % data_n].add(amt)
    return c


# ---------------------------------------------------------------------------
# The guarded GEMM path (NOT jitted here — see module docstring)
# ---------------------------------------------------------------------------

def guarded_gemm(x, w, scale=None, bias=None, *, guard: PodGuard,
                 activation: str | None = None, out_dtype=jnp.float32,
                 transpose: bool = False, interpret: bool | None = None):
    """Pod GEMM under a PodGuard: raw kernel -> (inject) -> verify/
    correct -> epilogue. x [M, K]; w [K, N] ([N, K] when ``transpose``).

    Registers (corrected, uncorrected) flags on the active GuardTape;
    standalone calls (no tape) just return the verified output. Blocks
    come from the autotuner at the ORIGINAL (M, K, N) so tile
    attribution matches the unguarded geometry.
    """
    if guard.mode == OFF:
        raise ValueError("guarded_gemm called with guard off — the caller "
                         "should take the unguarded path")
    M, K = x.shape
    N = w.shape[0] if transpose else w.shape[1]
    if guard.mode == ABFT and x.dtype == jnp.int8:
        raise ValueError("abft guard does not support int8 operands: the "
                         "column-sum checksum row overflows int8; use "
                         "mode='probe' or dequantize first")
    from .ops import _auto_blocks, _rup, systolic_gemm, systolic_gemm_t
    bm, bn, bk = _auto_blocks(M, K, N, x.dtype, out_dtype)

    tape = active_tape()
    idx = tape.next_index() if tape is not None else 0

    kern = systolic_gemm_t if transpose else systolic_gemm
    raw = dict(activation=None, out_dtype=jnp.float32, interpret=interpret,
               block_m=bm, block_n=bn, block_k=bk)
    if guard.mode == ABFT:
        x_aug = augment_x(x)
        w_aug = augment_wt(w) if transpose else augment_w(w)
        c_aug = kern(x_aug, w_aug, None, None, **raw)
        if tape is not None and tape.inject is not None:
            c_aug = inject_sdc(c_aug, idx, tape.inject, tape.magnitude,
                               M, N)
        c, report = abft_verify(c_aug, x, w, rtol=guard.rtol,
                                transpose=transpose)
        corrected, uncorrected = report["corrected"], report["uncorrected"]
    else:                                       # PROBE: detect-only
        c = kern(x, w, None, None, **raw)
        if tape is not None and tape.inject is not None:
            c = inject_sdc(c, idx, tape.inject, tape.magnitude, M, N)
        detected = freivalds_detect(
            c, x, w, probes=guard.probes, seed=guard.probe_seed,
            rtol=guard.rtol, transpose=transpose)
        corrected = jnp.int32(0)
        uncorrected = detected
    if tape is not None:
        tape.record(corrected, uncorrected)

    if scale is None:
        scale = jnp.ones((N,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    out = _epilogue_math(c, scale, bias, activation).astype(out_dtype)
    return out
