"""jit'd public wrapper for the systolic GEMM kernel: pads to block
multiples, dispatches to Pallas (interpret=True on CPU), slices back."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .systolic_gemm import systolic_gemm_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def systolic_gemm(x, w, scale=None, bias=None, *, activation=None,
                  block_m: int = 256, block_n: int = 256, block_k: int = 256,
                  out_dtype=jnp.float32, interpret: bool | None = None):
    """out = epilogue((x @ w) * scale + bias). x [M,K], w [K,N].

    int8 x int8 -> int32 accumulate; bf16/f32 -> f32 accumulate.
    The fused epilogue is the paper's SIMD post-processor (DESIGN.md §2).
    """
    if interpret is None:
        interpret = not _on_tpu()
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = (min(block_m, _rup(M)), min(block_n, _rup(N)),
                  min(block_k, _rup(K)))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    if scale is None:
        scale = jnp.ones((N,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    sp = _pad_to(scale, bn, 0)
    bp = _pad_to(bias, bn, 0)
    out = systolic_gemm_pallas(
        xp, wp, sp, bp, block_m=bm, block_n=bn, block_k=bk,
        activation=activation, out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]


def _rup(n: int, m: int = 8) -> int:
    """Round up to a multiple of the TPU sublane count."""
    return max(m, ((n + m - 1) // m) * m)
