"""jit'd public wrappers for the systolic GEMM kernels: pad to block
multiples, dispatch to Pallas (interpret=True on CPU), slice back.

Block geometry defaults to the DSE autotuner
(parallel.autoshard.choose_blocks — tile_stats-driven, VMEM-budget-aware,
lru-cached per shape; see systolic_gemm.py for the contract). Pass explicit
block_m/n/k to override.

`fused_lane_gemm` is the serving hot-loop entry point: all leading axes of
the activation collapse into the GEMM M axis, so a decode batch's per-lane
GEMVs execute as the ONE fused [lanes, K] @ [K, N] GEMM the multi-tenant
co-scheduling analysis (tenancy/) assumes. `grouped_gemm` runs G
independent GEMMs in one kernel launch (MoE experts / multi-tenant pods).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .systolic_gemm import (grouped_systolic_gemm_pallas,
                            systolic_gemm_nt_pallas, systolic_gemm_pallas)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _auto_blocks(m: int, k: int, n: int, dtype, out_dtype
                 ) -> tuple[int, int, int]:
    """DSE-tuned block geometry (lazy import keeps kernels importable
    without the parallel/ package and avoids a module cycle)."""
    from ...parallel.autoshard import choose_blocks
    return choose_blocks(m, k, n,
                         dtype_bytes=jnp.dtype(dtype).itemsize,
                         out_bytes=jnp.dtype(out_dtype).itemsize)


def _auto_blocks_grouped(g: int, m: int, k: int, n: int, dtype, out_dtype
                         ) -> tuple[int, int, int]:
    """Grouped-kernel geometry: the per-group problem is what the grid
    tiles, so the autotuner scores (m, k, n) with the group count only
    affecting the (uniform) traffic scale (see choose_blocks_grouped)."""
    from ...parallel.autoshard import choose_blocks_grouped
    return choose_blocks_grouped(g, m, k, n,
                                 dtype_bytes=jnp.dtype(dtype).itemsize,
                                 out_bytes=jnp.dtype(out_dtype).itemsize)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def systolic_gemm(x, w, scale=None, bias=None, *, activation=None,
                  block_m: int | None = None, block_n: int | None = None,
                  block_k: int | None = None,
                  out_dtype=jnp.float32, interpret: bool | None = None):
    """out = epilogue((x @ w) * scale + bias). x [M,K], w [K,N].

    int8 x int8 -> int32 accumulate; bf16/f32 -> f32 accumulate.
    The fused epilogue is the paper's SIMD post-processor (DESIGN.md §2).
    Blocks default to the tile_stats autotuner (choose_blocks).
    """
    if interpret is None:
        interpret = not _on_tpu()
    M, K = x.shape
    N = w.shape[1]
    if block_m is None or block_n is None or block_k is None:
        am, an, ak = _auto_blocks(M, K, N, x.dtype, out_dtype)
        block_m, block_n, block_k = (block_m or am, block_n or an,
                                     block_k or ak)
    bm, bn, bk = (min(block_m, _rup(M)), min(block_n, _rup(N)),
                  min(block_k, _rup(K)))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    if scale is None:
        scale = jnp.ones((N,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    sp = _pad_to(scale, bn, 0)
    bp = _pad_to(bias, bn, 0)
    out = systolic_gemm_pallas(
        xp, wp, sp, bp, block_m=bm, block_n=bn, block_k=bk,
        activation=activation, out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]


def fused_lane_gemm(x, w, scale=None, bias=None, *, activation=None,
                    out_dtype=None, interpret: bool | None = None,
                    block_m: int | None = None, block_n: int | None = None,
                    block_k: int | None = None, guard=None):
    """Fused-lane GEMM: x [..., K] @ w [K, N] -> [..., N].

    All leading axes of x (decode lanes, sequence positions, batch) fuse
    into the GEMM M axis — one pod GEMM instead of a fan of GEMVs, which
    is exactly the fused-lane shape tenancy/trace.py attributes to the
    engine's step-locked decode. Leading shape is restored on return.

    ``guard`` (a guard.PodGuard, or None) diverts to the SDC-checked
    path (ABFT checksums / Freivalds probe, guard.py); None or mode
    "off" takes the jitted unguarded kernel untouched — bit-identical
    to a build without the guard.
    """
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    out_dtype = jnp.float32 if out_dtype is None else out_dtype
    if guard is not None and guard.mode != "off":
        from .guard import guarded_gemm
        out = guarded_gemm(
            x.reshape(m, x.shape[-1]), w, scale, bias, guard=guard,
            activation=activation, out_dtype=out_dtype, interpret=interpret)
    else:
        out = systolic_gemm(
            x.reshape(m, x.shape[-1]), w, scale, bias, activation=activation,
            block_m=block_m, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret)
    return out.reshape(lead + (w.shape[1],))


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def systolic_gemm_t(x, w, scale=None, bias=None, *, activation=None,
                    block_m: int | None = None, block_n: int | None = None,
                    block_k: int | None = None,
                    out_dtype=jnp.float32, interpret: bool | None = None):
    """out = epilogue((x @ w.T) * scale + bias). x [M,K], w [N,K].

    The transposed-weight pod GEMM: w streams in its stored layout (no
    [K,N] transpose copy) — the tied-embedding unembed runs the [vocab, d]
    token table as the LM head directly. Same autotune/padding contract as
    `systolic_gemm` (the cost model is layout-invariant)."""
    if interpret is None:
        interpret = not _on_tpu()
    M, K = x.shape
    N = w.shape[0]
    if block_m is None or block_n is None or block_k is None:
        am, an, ak = _auto_blocks(M, K, N, x.dtype, out_dtype)
        block_m, block_n, block_k = (block_m or am, block_n or an,
                                     block_k or ak)
    bm, bn, bk = (min(block_m, _rup(M)), min(block_n, _rup(N)),
                  min(block_k, _rup(K)))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bn, 0), bk, 1)
    if scale is None:
        scale = jnp.ones((N,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((N,), jnp.float32)
    sp = _pad_to(scale, bn, 0)
    bp = _pad_to(bias, bn, 0)
    out = systolic_gemm_nt_pallas(
        xp, wp, sp, bp, block_m=bm, block_n=bn, block_k=bk,
        activation=activation, out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]


def fused_lane_gemm_t(x, w, scale=None, bias=None, *, activation=None,
                      out_dtype=None, interpret: bool | None = None,
                      block_m: int | None = None, block_n: int | None = None,
                      block_k: int | None = None, guard=None):
    """Fused-lane transposed GEMM: x [..., K] @ w [N, K]^T -> [..., N].
    The LM-head entry point: all decode lanes / sequence positions fuse
    into the M axis of ONE pod GEMM against the stored [vocab, d] table.
    ``guard`` as in `fused_lane_gemm` (transposed-layout checksums)."""
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    out_dtype = jnp.float32 if out_dtype is None else out_dtype
    if guard is not None and guard.mode != "off":
        from .guard import guarded_gemm
        out = guarded_gemm(
            x.reshape(m, x.shape[-1]), w, scale, bias, guard=guard,
            activation=activation, out_dtype=out_dtype, transpose=True,
            interpret=interpret)
    else:
        out = systolic_gemm_t(
            x.reshape(m, x.shape[-1]), w, scale, bias, activation=activation,
            block_m=block_m, block_n=block_n, block_k=block_k,
            out_dtype=out_dtype, interpret=interpret)
    return out.reshape(lead + (w.shape[0],))


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def grouped_gemm(x, w, scale=None, bias=None, *, activation=None,
                 block_m: int | None = None, block_n: int | None = None,
                 block_k: int | None = None,
                 out_dtype=jnp.float32, interpret: bool | None = None):
    """G independent GEMMs in ONE kernel launch: x [G,M,K] @ w [G,K,N]
    -> [G,M,N], with a per-group (scale, bias, activation) epilogue.
    Same padding/autotune contract as `systolic_gemm` (blocks are chosen
    for the per-group (M, K, N) problem)."""
    if interpret is None:
        interpret = not _on_tpu()
    G, M, K = x.shape
    N = w.shape[2]
    if block_m is None or block_n is None or block_k is None:
        am, an, ak = _auto_blocks_grouped(G, M, K, N, x.dtype, out_dtype)
        block_m, block_n, block_k = (block_m or am, block_n or an,
                                     block_k or ak)
    bm, bn, bk = (min(block_m, _rup(M)), min(block_n, _rup(N)),
                  min(block_k, _rup(K)))
    xp = _pad_to(_pad_to(x, bm, 1), bk, 2)
    wp = _pad_to(_pad_to(w, bk, 1), bn, 2)
    if scale is None:
        scale = jnp.ones((G, N), jnp.float32)
    if bias is None:
        bias = jnp.zeros((G, N), jnp.float32)
    sp = _pad_to(scale, bn, 1)
    bp = _pad_to(bias, bn, 1)
    out = grouped_systolic_gemm_pallas(
        xp, wp, sp, bp, block_m=bm, block_n=bn, block_k=bk,
        activation=activation, out_dtype=out_dtype, interpret=interpret)
    return out[:, :M, :N]


def _rup(n: int, m: int = 8) -> int:
    """Round up to a multiple of the TPU sublane count."""
    return max(m, ((n + m - 1) // m) * m)
