"""Pure-jnp oracle for the systolic GEMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def systolic_gemm_ref(x, w, scale=None, bias=None, *, activation=None,
                      out_dtype=jnp.float32):
    if x.dtype == jnp.int8:
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
    else:
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if scale is not None:
        acc = acc * scale.astype(jnp.float32)[None, :]
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    elif activation == "relu2":
        acc = jnp.square(jnp.maximum(acc, 0.0))
    return acc.astype(out_dtype)


def systolic_gemm_t_ref(x, w, scale=None, bias=None, *, activation=None,
                        out_dtype=jnp.float32):
    """Oracle for the transposed-weight variant: x [M,K] @ w [N,K]^T."""
    return systolic_gemm_ref(x, w.T, scale, bias, activation=activation,
                             out_dtype=out_dtype)
