"""Pallas TPU kernel: weight-stationary tiled GEMM — the SOSA pod.

TPU-native adaptation of the paper's systolic pod (DESIGN.md §2):

  * the (bm x bk x bn) VMEM block is the "pod array": weights stay resident
    in VMEM across the K-walk (weight-stationary), activations stream
    through, int32 partial sums accumulate in a VMEM scratch — the TPU
    analogue of the paper's psum-through-the-column flow;
  * the grid is ordered K-minor so the accumulator scratch carries partial
    sums across K steps exactly like the paper's psum chaining (§4.2);
  * the paper's SIMD post-processor (Fig 7) becomes the fused epilogue:
    dequant scale + bias + activation run in-kernel on the final K step,
    saving one full HBM round-trip of the output;
  * dtypes follow §5: int8 activations x int8 weights -> int32 accumulate
    (TPU MXU has no int16 accumulator; strictly wider than the paper's
    int16 psums) with an f32 dequant epilogue. A bf16 x bf16 -> f32 path
    serves the training stack.

Block shapes are the kernel-level output of the SOSA granularity DSE: lane
dims must be multiples of 128 (MXU), sublane multiples of 8/32.

Autotuner contract (parallel.autoshard.choose_blocks)
-----------------------------------------------------
Block geometry is no longer a static 256^3 default: when the ops.py
wrappers are called without explicit blocks, the DSE cost model picks them.
The mapping between the kernel and the analytical tiling model
(core.tiling.tile_stats) is exact:

  * ``block_k``  = the pod array's contraction rows (ArrayConfig.rows),
  * ``block_n``  = the pod array's output columns  (ArrayConfig.cols),
  * ``block_m``  = the activation rows streamed per tile (``k_part``),

so ``tile_stats([GemmSpec(M, K, N)], ArrayConfig(rows=block_k,
cols=block_n), k_part=block_m)`` returns exactly this kernel's grid counts:
``n_i = M/block_m`` x ``n_l = N/block_n`` x ``n_j = K/block_k`` (the RAW
psum-chain depth carried by the accumulator scratch). `choose_blocks`
scores every candidate geometry with a roofline over those counts —
max(padded-MAC compute, HBM block traffic) — and rejects candidates whose
VMEM working set (double-buffered x/w streaming blocks + accumulator +
output block) exceeds the budget (default 12 MiB of the ~16 MiB VMEM).
Results are lru-cached per (shape, dtype), so the serving hot loop pays
for an autotune once per distinct layer shape.

The grouped variant (`grouped_systolic_gemm_pallas`) adds a leading
group axis to the grid — G independent (M x K) @ (K x N) problems in one
kernel launch (MoE experts, multi-tenant fused lanes); block geometry and
the psum-chain walk are per-group identical.

The transposed-weight variant (`systolic_gemm_nt_pallas`) contracts
x [M, K] against w stored as [N, K] — out = x @ w.T — streaming w blocks
in their stored layout. This is the tied-embedding unembed shape: the
[vocab, d] token-embedding table serves as the LM head without ever
materializing a [d, vocab] transpose copy in HBM (at nemotron scale that
copy alone is 9.4 GB). The cost model is layout-invariant (same block
bytes, same grid walk), so `choose_blocks` scores it identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _accumulate(x, w, acc_ref):
    if x.dtype == jnp.int8:
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def _accumulate_nt(x, w, acc_ref):
    """acc += x [bm, bk] @ w[bn, bk]^T — contraction on the shared K axis,
    w consumed in its stored (transposed) layout."""
    pref = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=pref)


def _epilogue_math(acc, scale, bias, activation):
    """The paper's SIMD post-processor: dequant + bias + activation."""
    acc = acc.astype(jnp.float32)
    acc = acc * scale.astype(jnp.float32)                # dequant (per-col)
    acc = acc + bias.astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    elif activation == "relu2":
        acc = jnp.square(jnp.maximum(acc, 0.0))
    return acc


def _gemm_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
                 n_k: int, activation: str | None, out_dtype):
    """One (i, j, k) grid step: acc += x_blk @ w_blk; epilogue at k == last."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(x_ref[...], w_ref[...], acc_ref)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = _epilogue_math(
            acc_ref[...], scale_ref[...], bias_ref[...],
            activation).astype(out_dtype)


def systolic_gemm_pallas(
    x: jax.Array,                  # [M, K] int8 | bf16
    w: jax.Array,                  # [K, N] int8 | bf16
    scale: jax.Array,              # [N] f32 dequant scale (ones if None)
    bias: jax.Array,               # [N] f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    activation: str | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) pads to block multiples")
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(
        _gemm_kernel, n_k=n_k, activation=activation, out_dtype=out_dtype)
    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[
            # int32/f32 accumulator = the pod's psum registers
            pltpu.VMEM((block_m, block_n), acc_dtype),
        ],
        interpret=interpret,
    )(x, w, scale.reshape(1, N), bias.reshape(1, N))


def _grouped_gemm_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref,
                         *, n_k: int, activation: str | None, out_dtype):
    """One (g, i, j, k) grid step of G independent GEMMs (K-minor walk)."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(x_ref[0], w_ref[0], acc_ref)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[0] = _epilogue_math(
            acc_ref[...], scale_ref[0], bias_ref[0],
            activation).astype(out_dtype)


def grouped_systolic_gemm_pallas(
    x: jax.Array,                  # [G, M, K] int8 | bf16
    w: jax.Array,                  # [G, K, N]
    scale: jax.Array,              # [G, N] f32 per-group dequant scale
    bias: jax.Array,               # [G, N] f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    activation: str | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """G independent pods in one launch: grid grows a leading group axis,
    every group walks its own K-minor psum chain through the shared
    accumulator scratch (groups are grid-major, so the scratch is reused
    group after group exactly as it is tile after tile)."""
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) pads to block multiples")
    n_k = K // block_k
    grid = (G, M // block_m, N // block_n, n_k)

    kernel = functools.partial(
        _grouped_gemm_kernel, n_k=n_k, activation=activation,
        out_dtype=out_dtype)
    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, block_k, block_n), lambda g, i, j, k: (g, k, j)),
            pl.BlockSpec((1, 1, block_n), lambda g, i, j, k: (g, 0, j)),
            pl.BlockSpec((1, 1, block_n), lambda g, i, j, k: (g, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), acc_dtype),
        ],
        interpret=interpret,
    )(x, w, scale.reshape(G, 1, N), bias.reshape(G, 1, N))


def _gemm_nt_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
                    n_k: int, activation: str | None, out_dtype):
    """One (i, j, k) grid step of the transposed-weight walk:
    acc += x_blk @ w_blk^T; epilogue at k == last."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_nt(x_ref[...], w_ref[...], acc_ref)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = _epilogue_math(
            acc_ref[...], scale_ref[...], bias_ref[...],
            activation).astype(out_dtype)


def systolic_gemm_nt_pallas(
    x: jax.Array,                  # [M, K] int8 | bf16
    w: jax.Array,                  # [N, K] — stored transposed (tied embed)
    scale: jax.Array,              # [N] f32 dequant scale (ones if None)
    bias: jax.Array,               # [N] f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    activation: str | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """out = epilogue((x @ w.T) * scale + bias) with w in [N, K] layout.
    Same K-minor psum-chain grid as `systolic_gemm_pallas`; only the w
    BlockSpec walks (j, k) instead of (k, j)."""
    M, K = x.shape
    N, K2 = w.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "caller (ops.py) pads to block multiples")
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(
        _gemm_nt_kernel, n_k=n_k, activation=activation, out_dtype=out_dtype)
    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), acc_dtype),
        ],
        interpret=interpret,
    )(x, w, scale.reshape(1, N), bias.reshape(1, N))
