import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x applicable shape x mesh) cell:
    jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)
        -> .compile() -> memory_analysis() + cost_analysis() + HLO text
No arrays are ever materialized (pure AOT on placeholder devices).

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Results land in reports/dryrun/<mesh>/<arch>__<shape>.json, which
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks read.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_arch, list_archs
from repro.models.model import Model
from repro.parallel.sharding import (batch_axes, cache_pspecs,
                                     fsdp_pspecs_from_schema, make_constrain,
                                     pspecs_from_schema, zero1_pspec)
from repro.roofline.analysis import (HBM_PER_CHIP, collective_bytes_from_hlo,
                                     from_compiled, model_flops)
from repro.train.optimizer import AdamWConfig, AdamWState
from repro.train.train_step import TrainConfig, grads_fn
from repro.launch.mesh import make_production_mesh, mesh_shape_dict

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def kv_replication(cfg, mesh) -> int:
    """Virtual-KV factor so decode caches shard over the model axis."""
    m = mesh.shape.get("model", 1)
    kv = max(1, cfg.n_kv_heads)
    if cfg.mla is not None or cfg.family == "ssm":
        return 1
    if kv < m and m % kv == 0 and cfg.n_heads % m == 0:
        return m // kv
    return 1


def input_specs(arch: str, shape_name: str, mesh=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell (public
    entry used by the dry-run and the benchmarks; no allocation)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((B, S if not shape.is_decode else 1), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), jnp.int32)
    if cfg.encoder_decoder and not shape.is_decode:
        specs["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm" and not shape.is_decode:
        specs["image_embeds"] = _sds((B, cfg.n_image_tokens, cfg.d_model),
                                     jnp.bfloat16)
    return specs


def _microbatches(arch: str, shape_name: str) -> int:
    """Grad-accum for the big train cells (activation fit; §Perf logs)."""
    if shape_name != "train_4k":
        return 1
    return {"nemotron-4-340b": 4, "llama-3.2-vision-90b": 2,
            "deepseek-v2-236b": 2, "dbrx-132b": 2}.get(arch, 1)


def calibration_cfgs(cfg):
    """Two reduced-DEPTH (same width!) variants whose scanned segments hold
    1 vs 2 layers, plus the per-layer extrapolation count.

    XLA's cost analysis counts a while (scan) body once regardless of trip
    count, so scanned compiles under-report FLOPs / bytes / collectives.
    Every scanned layer is identical by construction, so
        total(L) = f(1) + (f(2) - f(1)) * extra
    with f() measured on small *unrolled* compiles under the same mesh and
    shardings is exact for dots (validated in tests/test_roofline.py).
    """
    import dataclasses as dc
    fam = cfg.family
    if fam == "vlm":
        g = cfg.cross_attn_every
        return (dc.replace(cfg, n_layers=g), dc.replace(cfg, n_layers=2 * g),
                cfg.n_layers // g - 1)
    if fam == "hybrid":
        c1 = dc.replace(cfg, n_layers=2, global_attn_layers=(0,))
        c2 = dc.replace(cfg, n_layers=3, global_attn_layers=(0,))
        # globals cost ~= SWA layers (masking is free); 1 global is in f;
        # remaining layers (incl. the other globals) extrapolate as SWA.
        return c1, c2, cfg.n_layers - 2
    if cfg.moe and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        return (dc.replace(cfg, n_layers=fd + 1),
                dc.replace(cfg, n_layers=fd + 2),
                cfg.n_layers - fd - 1)
    if cfg.encoder_decoder:
        return (dc.replace(cfg, n_layers=1, n_encoder_layers=1),
                dc.replace(cfg, n_layers=2, n_encoder_layers=2),
                cfg.n_layers - 1)
    return (dc.replace(cfg, n_layers=1), dc.replace(cfg, n_layers=2),
            cfg.n_layers - 1)


def build_cell(arch: str, shape_name: str, mesh, seq_shard: bool = True,
               include_optimizer: bool = True, cfg_override=None,
               unroll: bool = False, microbatches: int | None = None,
               opts: dict | None = None):
    """Returns (fn, args_sds, in_shardings, donate) ready to lower.

    opts — §Perf hillclimb knobs:
      moe_dispatch: "onehot"|"sort"      (MoE data-movement strategy)
      mla_seq_shard: bool                (latent-cache sequence sharding)
      kv_block: int                      (chunked-attention block size)
      no_seq_shard / no_fsdp: bool
    """
    import dataclasses as dc
    opts = opts or {}
    cfg = cfg_override if cfg_override is not None else get_arch(arch)
    if opts.get("moe_dispatch") and cfg.moe:
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe,
                                             dispatch=opts["moe_dispatch"]))
    if opts.get("moe_group_size") and cfg.moe:
        cfg = dc.replace(cfg, moe=dc.replace(
            cfg.moe, group_size=opts["moe_group_size"]))
    if opts.get("router_bf16") and cfg.moe:
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe,
                                             router_dtype="bfloat16"))
    shape = SHAPES[shape_name]
    kv_rep = kv_replication(cfg, mesh) if shape.is_decode else 1
    attn_sp = opts.get("attn_seq_parallel", False)
    use_sp = (seq_shard and shape.kind == "train") or \
        (attn_sp and not shape.is_decode)
    constrain = make_constrain(mesh, cfg.vocab, seq_shard=use_sp)
    model = Model(cfg, kv_rep=kv_rep, constrain=constrain, unroll=unroll,
                  remat=shape.kind == "train",
                  kv_block=opts.get("kv_block", 1024))

    sch = model.schema()
    # FSDP (params dp-sharded, per-layer gather/reduce-scatter) for every
    # train cell and for serving cells whose TP-sharded weights would not
    # fit HBM alongside the KV cache.
    from repro.models.layers import param_count
    from repro.parallel.sharding import ATTN_SP_RULES
    rules = ATTN_SP_RULES if attn_sp else None
    tp = mesh.shape.get("model", 1)
    params_gb_tp = param_count(sch) * 2 / tp / 2 ** 30
    use_fsdp = shape.kind == "train" or params_gb_tp > 8.0
    p_specs = (fsdp_pspecs_from_schema(sch, mesh, rules) if use_fsdp
               else pspecs_from_schema(sch, mesh, rules))
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    params_sds = model.shapes()

    dp = batch_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    batch_specs = input_specs(arch, shape_name, mesh)
    b_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*((dpa,) + (None,) * (len(s.shape) - 1)))),
        batch_specs)

    if shape.kind == "train":
        ub = microbatches if microbatches is not None else \
            _microbatches(arch, shape_name)
        ocfg = AdamWConfig(
            moment_dtype="bfloat16" if opts.get("opt_bf16") else "float32")
        tcfg = TrainConfig(microbatches=ub, optimizer=ocfg)
        gf = grads_fn(model, tcfg)
        if include_optimizer:
            from repro.train.optimizer import adamw_update

            def step(params, opt_state, batch):
                loss, grads = gf(params, batch)
                new_params, new_opt, om = adamw_update(
                    tcfg.optimizer, opt_state, grads)
                return new_params, new_opt, {"loss": loss, **om}

            # ZeRO-1: master/m/v sharded over DP axes on top of TP
            def z1(sds_tree):
                return jax.tree.map(
                    lambda sds, ps: NamedSharding(
                        mesh, zero1_pspec(ps, sds.shape, mesh)),
                    sds_tree, p_specs)
            mdt = jnp.bfloat16 if opts.get("opt_bf16") else jnp.float32
            cast = lambda t, dt: jax.tree.map(
                lambda s: _sds(s.shape, dt), t)
            f32p = cast(params_sds, jnp.float32)
            mom = cast(params_sds, mdt)
            opt_sds = AdamWState(_sds((), jnp.int32), f32p, mom, mom)
            opt_shard = AdamWState(
                NamedSharding(mesh, P()), z1(f32p), z1(mom), z1(mom))
            return (step, (params_sds, opt_sds, batch_specs),
                    (p_shard, opt_shard, b_shard), (0, 1))

        def step(params, batch):
            return gf(params, batch)
        return step, (params_sds, batch_specs), (p_shard, b_shard), ()

    # serving cells
    max_len = shape.seq_len
    src_len = shape.seq_len if cfg.encoder_decoder else cfg.n_image_tokens
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, max_len,
                                 src_len=src_len))
    c_specs = cache_pspecs(cache_sds, mesh,
                           mla_seq_shard=opts.get("mla_seq_shard", False),
                           kv_seq_shard=opts.get("kv_seq_shard", False))
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)

    if shape.kind == "prefill":
        def step(params, batch, cache):
            return model.prefill(params, batch, cache)
        return (step, (params_sds, batch_specs, cache_sds),
                (p_shard, b_shard, c_shard), (2,))

    # decode / long_decode: one token against a filled cache
    tok_sds = _sds((shape.global_batch,), jnp.int32)
    tok_shard = NamedSharding(mesh, P(dpa if shape.global_batch > 1 else None))
    pos_sds = _sds((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())

    def step(params, tokens, cache, position):
        return model.decode_step(params, tokens, cache, position)
    return (step, (params_sds, tok_sds, cache_sds, pos_sds),
            (p_shard, tok_shard, c_shard, pos_shard), (2,))


def _compile_cell(arch, shape_name, mesh, **kw):
    fn, args, shardings, donate = build_cell(arch, shape_name, mesh, **kw)
    with mesh:
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        return lowered.compile()


def _terms(compiled, chips, name="", kinds: dict | None = None):
    rl = from_compiled(name, compiled, chips)
    if kinds is not None and rl.collective_by_kind:
        for k, v in rl.collective_by_kind.items():
            kinds[k] = kinds.get(k, 0) + v
    return (rl.flops_per_device, rl.bytes_per_device,
            rl.collective_bytes_per_device)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             seq_shard: bool = True, save: bool = True,
             include_optimizer: bool = True, tag: str = "",
             calibrate: bool = True, opts: dict | None = None) -> dict:
    """One dry-run cell: full scanned compile (memory fit, HLO) + L1/L2
    unrolled calibration compiles (exact FLOP/byte/collective totals —
    cost analysis counts scan bodies once, see calibration_cfgs)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "status": "ok", "opts": opts or {}}
    try:
        compiled = _compile_cell(
            arch, shape_name, mesh, seq_shard=seq_shard,
            include_optimizer=include_optimizer, opts=opts)
        mem = compiled.memory_analysis()
        f_raw, b_raw, c_raw = _terms(compiled, chips)
        result.update({"flops_per_device_scanned": f_raw,
                       "bytes_per_device_scanned": b_raw,
                       "collective_bytes_per_device_scanned": c_raw})
        del compiled

        if calibrate:
            c1, c2, extra = calibration_cfgs(cfg)
            ckw = dict(seq_shard=seq_shard,
                       include_optimizer=include_optimizer,
                       unroll=True, microbatches=1, opts=opts)
            k1: dict = {}
            k2: dict = {}
            f1 = _terms(_compile_cell(arch, shape_name, mesh,
                                      cfg_override=c1, **ckw), chips,
                        kinds=k1)
            f2 = _terms(_compile_cell(arch, shape_name, mesh,
                                      cfg_override=c2, **ckw), chips,
                        kinds=k2)
            # per-layer deltas clamped >= 0: XLA occasionally CSEs an
            # all-gather in the deeper variant, which would extrapolate
            # to a (meaningless) negative total
            flops, nbytes, coll = (a + max(0.0, b - a) * extra
                                   for a, b in zip(f1, f2))
            result["calibration"] = {
                "l1": f1, "l2": f2, "extra_layers": extra}
            result["collective_by_kind_per_device"] = {
                k: k1.get(k, 0) + (k2.get(k, 0) - k1.get(k, 0)) * extra
                for k in set(k1) | set(k2)}
        else:
            flops, nbytes, coll = f_raw, b_raw, c_raw

        from repro.roofline.analysis import Roofline
        rl = Roofline(name=f"{arch}__{shape_name}", chips=chips,
                      flops_per_device=flops, bytes_per_device=nbytes,
                      collective_bytes_per_device=coll)
        tokens = shape.global_batch * (shape.seq_len if shape.kind in
                                       ("train", "prefill") else 1)
        # 6ND convention: N excludes the input-embedding table (a gather,
        # not matmul flops); the unembedding projection stays counted.
        n_active = cfg.active_params_estimate() - cfg.vocab * cfg.d_model
        mf = model_flops(n_active, tokens, train=shape.kind == "train")
        result.update(rl.to_dict(mf))
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    result[attr] = int(v)
            args_b = result.get("argument_size_in_bytes", 0)
            tmp_b = result.get("temp_size_in_bytes", 0)
            result["hbm_fit"] = bool((args_b + tmp_b) <= HBM_PER_CHIP)
            result["hbm_gb_per_chip"] = (args_b + tmp_b) / 2 ** 30
        result["compile_s"] = round(time.time() - t0, 1)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        result["compile_s"] = round(time.time() - t0, 1)
    if save:
        outdir = os.path.join(REPORT_DIR, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        fname = f"{arch}__{shape_name}{tag}.json"
        with open(os.path.join(outdir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-optimizer", action="store_true")
    ap.add_argument("--tag", type=str, default="")
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in applicable_shapes(get_arch(arch)):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    for mp in meshes:
        for arch, shape in cells:
            r = run_cell(arch, shape, multi_pod=mp,
                         seq_shard=not args.no_seq_shard,
                         include_optimizer=not args.no_optimizer,
                         tag=args.tag)
            flag = "OK " if r["status"] == "ok" else "ERR"
            extra = (f"hbm={r.get('hbm_gb_per_chip', 0):.2f}GB "
                     f"bottleneck={r.get('bottleneck')}"
                     if r["status"] == "ok" else r.get("error", ""))
            print(f"[{flag}] {r['mesh']:16s} {arch:22s} {shape:12s} "
                  f"compile={r['compile_s']:7.1f}s {extra}", flush=True)


if __name__ == "__main__":
    main()
