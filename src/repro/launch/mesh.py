"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax
import, smoke tests see the real single device.

Mesh axes:
  pod   — inter-pod (DCN-ish) axis: only DP gradient reduction crosses it,
          overlapped + int8-compressible (parallel/compression.py)
  data  — intra-pod data parallel / ZeRO-1 axis
  model — tensor/expert parallel axis (ICI-local)
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever fits the local host (tests / examples): (data, model)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
