"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 6 --slots 3 --max-new 12 \
        --metrics --trace-out /tmp/serve_trace.json

`--metrics` prints the engine's telemetry snapshot (obs.metrics) after the
run; `--trace-out PATH` writes the run as Chrome trace-event JSON —
drag-and-drop it into ui.perfetto.dev or chrome://tracing.

Overload & failure knobs (serve/admission.py, serve/chaos.py):
`--policy {fifo,edf,slo-aware}` selects the admission policy, `--deadline
SECONDS` stamps every generated request with that deadline, `--max-queue N`
bounds the queue (backpressure: over-budget submissions are shed with
`Request.state == "rejected"`), and `--chaos-*` arm the seeded fault
injector so the retry/shedding machinery is observable from the CLI.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models.model import Model
from repro.serve.admission import AdmissionConfig, POLICIES
from repro.serve.chaos import ChaosConfig
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--metrics", action="store_true",
                    help="print the obs.metrics snapshot after the run")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="write the run as Perfetto/Chrome trace JSON")
    ap.add_argument("--policy", choices=POLICIES, default="fifo",
                    help="admission policy (serve/admission.py)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request deadline in seconds from submit")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="bounded queue: shed submissions beyond N queued")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="arm the fault injector with this seed")
    ap.add_argument("--chaos-fault-p", type=float, default=0.1,
                    help="per-call transient-fault probability")
    ap.add_argument("--chaos-slow-p", type=float, default=0.1,
                    help="per-call slow-chunk probability")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    metrics = tracer = None
    if args.metrics:
        from repro.obs.metrics import MetricsRegistry
        metrics = MetricsRegistry()
    if args.trace_out:
        from repro.tenancy.trace import ServeTraceRecorder
        tracer = ServeTraceRecorder()
    chaos = None
    if args.chaos_seed is not None:
        chaos = ChaosConfig(seed=args.chaos_seed,
                            p_fault=args.chaos_fault_p,
                            p_slow=args.chaos_slow_p)
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len, metrics=metrics,
                         tracer=tracer, chaos=chaos,
                         admission=AdmissionConfig(
                             policy=args.policy, max_queue=args.max_queue))

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 24),
                              dtype=np.int32)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new,
                    deadline_s=args.deadline)
        reqs.append(r)
        engine.submit(r)
    steps = 0
    while engine.queue or any(engine.active):
        engine.step()
        steps += 1
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    for r in reqs:
        tail = "" if r.state == "done" else \
            f"  [{r.state}{': ' + r.reason if r.reason else ''}]"
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.out}{tail}")
    print(f"{args.requests} requests, {total_new} tokens, {steps} engine "
          f"steps, {dt:.1f}s ({1000 * dt / max(1, total_new):.0f} ms/tok "
          f"on CPU)")
    c = engine.admission.counts
    if c["rejected"] or c["expired"] or args.deadline is not None:
        print(f"admission[{args.policy}]: {c}; "
              f"slo_attainment={engine.admission.slo_attainment:.2f}")
    if metrics is not None:
        print("metrics snapshot:")
        print(metrics.dumps(indent=1))
    if tracer is not None:
        from repro.obs.export import write_chrome_trace
        n = write_chrome_trace(args.trace_out, tracer.spans)
        print(f"wrote {n} spans to {args.trace_out} "
              f"(open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
