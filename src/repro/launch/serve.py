"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 6 --slots 3 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 24),
                              dtype=np.int32)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        engine.submit(r)
    steps = 0
    while engine.queue or any(engine.active):
        engine.step()
        steps += 1
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.out}")
    print(f"{args.requests} requests, {total_new} tokens, {steps} engine "
          f"steps, {dt:.1f}s ({1000 * dt / max(1, total_new):.0f} ms/tok "
          f"on CPU)")


if __name__ == "__main__":
    main()
