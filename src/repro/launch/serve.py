"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --requests 6 --slots 3 --max-new 12 \
        --metrics --trace-out /tmp/serve_trace.json

`--metrics` prints the engine's telemetry snapshot (obs.metrics) after the
run; `--trace-out PATH` writes the run as Chrome trace-event JSON —
drag-and-drop it into ui.perfetto.dev or chrome://tracing.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--metrics", action="store_true",
                    help="print the obs.metrics snapshot after the run")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="write the run as Perfetto/Chrome trace JSON")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    metrics = tracer = None
    if args.metrics:
        from repro.obs.metrics import MetricsRegistry
        metrics = MetricsRegistry()
    if args.trace_out:
        from repro.tenancy.trace import ServeTraceRecorder
        tracer = ServeTraceRecorder()
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len, metrics=metrics,
                         tracer=tracer)

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 24),
                              dtype=np.int32)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        engine.submit(r)
    steps = 0
    while engine.queue or any(engine.active):
        engine.step()
        steps += 1
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.out}")
    print(f"{args.requests} requests, {total_new} tokens, {steps} engine "
          f"steps, {dt:.1f}s ({1000 * dt / max(1, total_new):.0f} ms/tok "
          f"on CPU)")
    if metrics is not None:
        print("metrics snapshot:")
        print(metrics.dumps(indent=1))
    if tracer is not None:
        from repro.obs.export import write_chrome_trace
        n = write_chrome_trace(args.trace_out, tracer.spans)
        print(f"wrote {n} spans to {args.trace_out} "
              f"(open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
