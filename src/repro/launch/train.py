"""Training driver (runnable end-to-end on this CPU host).

    PYTHONPATH=src python -m repro.launch.train \
        --arch yi-6b --reduced --steps 40 --batch 8 --seq 128 \
        --ckpt-dir /tmp/ckpt --ckpt-every 10 [--resume] [--kill-at 25]

Production posture: sharded params (logical-axis rules over the host
mesh), AdamW + ZeRO-1, deterministic resumable data stream, atomic
checkpoints, straggler/heartbeat hooks (train/fault.py). `--kill-at N`
simulates a mid-run failure; re-running with --resume picks up from the
newest COMMITTED checkpoint and reproduces the same batch stream.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models.model import Model
from repro.train.checkpoint import (latest_step, prune_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.data import DataConfig, batches
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.train_step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="simulate a host failure after N steps")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = Model(cfg, remat=True)
    print(f"arch={cfg.name} params={model.param_count() / 1e6:.1f}M")

    ocfg = AdamWConfig(lr_peak=args.lr, warmup_steps=5,
                       total_steps=args.steps)
    tcfg = TrainConfig(microbatches=args.microbatches, optimizer=ocfg)
    train_step = jax.jit(make_train_step(model, tcfg),
                         donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_adamw(params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    stream = batches(dcfg, start_step=start)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / max(1, step - start + 1):.2f}s/it)",
                  flush=True)
        if (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1,
                                   (params, opt_state))
            prune_checkpoints(args.ckpt_dir, keep=3)
            print(f"checkpointed -> {path}")
        if args.kill_at is not None and step + 1 >= args.kill_at:
            print(f"simulated failure at step {step + 1} "
                  f"(restart with --resume)")
            raise SystemExit(42)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
