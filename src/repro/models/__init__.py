"""Model zoo: the 10 assigned architectures via one segment-based API."""

from .model import CrossKV, Model
from .attention import KVCache, RingKVCache, chunked_attention, naive_attention
from .ssm import SSMCache, apply_ssm, ssd_reference
from .transformer import MLACache, Segment, segments

__all__ = ["Model", "CrossKV", "KVCache", "RingKVCache", "MLACache",
           "SSMCache", "Segment", "segments", "chunked_attention",
           "naive_attention", "apply_ssm", "ssd_reference"]
