"""Attention: GQA with chunked (flash-style) softmax, sliding windows,
decode-with-cache, and DeepSeek-V2 MLA (low-rank latent attention).

The production prefill/train path is `chunked_attention`: a lax.scan over
KV blocks with an online softmax — O(S) memory, compiles on any backend,
and is the pure-JAX mirror of kernels/flash_attention (which is the Pallas
TPU version of the same blocking; the block sizes come from the same SOSA
granularity analysis, see parallel/autoshard.py).

Shapes: q [B, Sq, Hq, D], k/v [B, Skv, Hkv, D] with Hq = G * Hkv (GQA).
All masks are computed from positions (never materialized [S, S] tensors).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q [B,Sq,Hkv,G,D] x k [B,Skv,Hkv,D] -> [B,Hkv,G,Sq,Skv]."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k)


def _gqa_out(p, v):
    """p [B,Hkv,G,Sq,Skv] x v [B,Skv,Hkv,D] -> [B,Sq,Hkv,G,D]."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


def _mask_bias(q_pos, k_pos, causal: bool, window: int | None):
    """[Sq, Skv] additive bias from position comparisons."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF)


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_block: int = 1024,
    softmax_scale: float | None = None,
    kv_valid_len=None,
):
    """Flash attention with a *flash backward* (custom VJP).

    Autodiff of a scanned online-softmax saves score-sized residuals per
    KV block — O(S²) f32 bytes, measured as the dominant HBM term on the
    MLA train cells (EXPERIMENTS §Perf cell 1). The custom VJP saves only
    (q, k, v, O, rowwise logsumexp) and recomputes scores blockwise in the
    backward — the defining trick of flash attention, here at the XLA/JAX
    level so it also shapes the dry-run roofline.
    """
    if window is None and kv_valid_len is None and q_offset == 0:
        return _flash_vjp(q, k, v, causal, kv_block, softmax_scale)
    return _chunked_attention_fwd_only(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_block=kv_block, softmax_scale=softmax_scale,
        kv_valid_len=kv_valid_len)


def _chunked_attention_fwd_only(
    q, k, v, *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    kv_block: int = 1024,
    softmax_scale: float | None = None,
    kv_valid_len=None,
):
    """Scanned online-softmax forward (all mask variants; used directly
    for serving paths and as the recompute body of the custom VJP)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]            # may differ from D (MLA: qk 192, v 128)
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)

    nb = -(-Skv // kv_block)
    pad = nb * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)

    qg = (q * scale).reshape(B, Sq, Hkv, G, D)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, blk):
        acc, m, l, idx = carry
        kblk, vblk = blk
        k_pos = idx * kv_block + jnp.arange(kv_block)
        s = _gqa_scores(qg, kblk).astype(jnp.float32)       # [B,Hkv,G,Sq,kb]
        bias = _mask_bias(q_pos, k_pos, causal, window)
        if kv_valid_len is not None:
            bias = bias + jnp.where(k_pos[None, :] < kv_valid_len, 0.0, NEG_INF)
        if pad:
            bias = bias + jnp.where(k_pos[None, :] < Skv, 0.0, NEG_INF)
        s = s + bias
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + _gqa_out(
            p.astype(q.dtype), vblk).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
        return (acc_new, m_new, l_new, idx + 1), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(step, (acc0, m0, l0, 0), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # [B,Hkv,G,Sq,Dv]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention with flash backward (custom VJP)
# ---------------------------------------------------------------------------

def _flash_fwd_pass(q, k, v, causal, kv_block, softmax_scale):
    """Forward returning (out, L) with L = rowwise logsumexp [B,Hkv,G,Sq]."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    nb = -(-Skv // kv_block)
    pad = nb * kv_block - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kb_ = kp.reshape(B, nb, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb_ = vp.reshape(B, nb, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    qg = (q * scale).reshape(B, Sq, Hkv, G, D)
    q_pos = jnp.arange(Sq)

    def step(carry, blk):
        acc, m, l, idx = carry
        kblk, vblk = blk
        k_pos = idx * kv_block + jnp.arange(kv_block)
        s = _gqa_scores(qg, kblk).astype(jnp.float32)
        ok = k_pos[None, :] < Skv
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + _gqa_out(
            p.astype(q.dtype), vblk).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
        return (acc_new, m_new, l_new, idx + 1), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(
        jax.checkpoint(step), (acc0, m0, l0, 0), (kb_, vb_))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(
        0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv).astype(q.dtype)
    L = m + jnp.log(jnp.maximum(l, 1e-30))           # [B,Hkv,G,Sq]
    return out, L


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_vjp(q, k, v, causal, kv_block, softmax_scale):
    out, _ = _flash_fwd_pass(q, k, v, causal, kv_block, softmax_scale)
    return out


def _flash_vjp_fwd(q, k, v, causal, kv_block, softmax_scale):
    out, L = _flash_fwd_pass(q, k, v, causal, kv_block, softmax_scale)
    return out, (q, k, v, out, L)


def _flash_vjp_bwd(causal, kv_block, softmax_scale, res, dout):
    """Flash backward: recompute scores blockwise from (q, k, v, L);
    residuals are O(S·D), never O(S²)."""
    q, k, v, out, L = res
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    nb = -(-Skv // kv_block)
    pad = nb * kv_block - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kb_ = kp.reshape(B, nb, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb_ = vp.reshape(B, nb, kv_block, Hkv, Dv).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, Hkv, G, D)
    dog = dout.reshape(B, Sq, Hkv, G, Dv)            # [B,Sq,Hkv,G,Dv]
    # Delta = rowsum(dO * O)  [B,Hkv,G,Sq]
    delta = jnp.einsum("bqhgd,bqhgd->bhgq",
                       dog.astype(jnp.float32),
                       out.reshape(B, Sq, Hkv, G, Dv).astype(jnp.float32))
    q_pos = jnp.arange(Sq)

    def step(dq_acc, blk):
        kblk, vblk, idx = blk
        k_pos = idx * kv_block + jnp.arange(kv_block)
        s = _gqa_scores(qg, kblk).astype(jnp.float32) * scale
        ok = k_pos[None, :] < Skv
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(ok, s, NEG_INF)
        p = jnp.exp(s - L[..., None])                 # [B,Hkv,G,Sq,kb]
        # dv_j = sum_{q,g} p * dO
        dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p.astype(dout.dtype), dog)
        # dp = dO . v^T
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vblk).astype(jnp.float32)
        ds = p * (dp - delta[..., None]) * scale      # [B,Hkv,G,Sq,kb]
        dsq = ds.astype(q.dtype)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", dsq, kblk
                                     ).astype(jnp.float32)
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", dsq, qg.astype(q.dtype))
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        jax.checkpoint(step), dq0,
        (kb_, vb_, jnp.arange(nb)))
    dq = dq.reshape(B, Sq, Hq, D).astype(q.dtype)
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, nb * kv_block, Hkv, D)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, nb * kv_block, Hkv, Dv)
    if pad:
        dk, dv = dk[:, :Skv], dv[:, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def naive_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    softmax_scale=None, kv_valid_len=None):
    """Reference implementation (materializes [Sq, Skv] scores)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, Sq, Hkv, G, D)
    s = _gqa_scores(qg, k).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    if kv_valid_len is not None:
        bias = bias + jnp.where(k_pos[None, :] < kv_valid_len, 0.0, NEG_INF)
    p = jax.nn.softmax(s + bias, axis=-1).astype(q.dtype)
    out = _gqa_out(p, v)                                   # [B,Sq,Hkv,G,Dv]
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def attention(q, k, v, *, impl: str = "chunked", **kw):
    if impl == "chunked":
        return chunked_attention(q, k, v, **kw)
    if impl == "naive":
        kw.pop("kv_block", None)
        return naive_attention(q, k, v, **kw)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fl
        kw.pop("kv_block", None)
        return fl.flash_attention(q, k, v, **kw)
    raise ValueError(impl)


# --------------------------------------------------------------------------
# KV caches
# --------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    """Functional KV cache. `k`/`v`: [..., B, S_max, H, D] (optional leading
    layer axis when stacked for scan); `length`: [B] filled positions —
    per-lane, so the serving engine can continuous-batch mixed-length
    requests in one cache pytree."""
    k: jax.Array
    v: jax.Array
    length: jax.Array   # [B] int32 (stacked: [L, B])

    @staticmethod
    def zeros(batch, max_len, n_kv, head_dim, dtype=jnp.bfloat16,
              layers: int | None = None):
        shape = (batch, max_len, n_kv, head_dim)
        lshape: tuple[int, ...] = (batch,)
        if layers:
            shape = (layers,) + shape
            lshape = (layers, batch)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros(lshape, jnp.int32))

    def append(self, k_new, v_new):
        """Write [B, s, H, D] at per-lane position `length` (no layer axis
        here — per-layer views are sliced inside the scan body)."""
        idx = self.length                            # [B]
        upd = jax.vmap(
            lambda buf, new, i: jax.lax.dynamic_update_slice_in_dim(
                buf, new, i, axis=0))
        k = upd(self.k, k_new, idx)
        v = upd(self.v, v_new, idx)
        return KVCache(k, v, idx + k_new.shape[1])


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "length"], meta_fields=[])


@dataclasses.dataclass
class RingKVCache:
    """Sliding-window ring buffer (window-sized memory for SWA layers)."""
    k: jax.Array        # [B, W, H, D]
    v: jax.Array
    length: jax.Array   # [B] total tokens seen per lane

    @staticmethod
    def zeros(batch, window, n_kv, head_dim, dtype=jnp.bfloat16):
        return RingKVCache(
            jnp.zeros((batch, window, n_kv, head_dim), dtype),
            jnp.zeros((batch, window, n_kv, head_dim), dtype),
            jnp.zeros((batch,), jnp.int32))

    @property
    def window(self) -> int:
        return self.k.shape[1]

    def append_token(self, k_new, v_new):
        """k_new [B, 1, H, D] — single decode step, per-lane ring slots."""
        slot = self.length % self.window             # [B]
        upd = jax.vmap(
            lambda buf, new, i: jax.lax.dynamic_update_slice_in_dim(
                buf, new, i, axis=0))
        k = upd(self.k, k_new, slot)
        v = upd(self.v, v_new, slot)
        return RingKVCache(k, v, self.length + 1)

    def positions(self):
        """Absolute position stored in each ring slot per lane [B, W]
        (invalid slots -> -1, masked by callers)."""
        W = self.window
        slots = jnp.arange(W)[None, :]
        newest = (self.length - 1)[:, None]          # [B, 1]
        newest_slot = newest % W
        age = (newest_slot - slots) % W
        pos = newest - age
        return jnp.where((pos >= 0) & (pos > newest - W), pos, -1)


jax.tree_util.register_dataclass(
    RingKVCache, data_fields=["k", "v", "length"], meta_fields=[])


@dataclasses.dataclass
class PagedKVCache:
    """Pooled (paged) KV cache for serving: device memory scales with the
    pages actually mapped, not `slots x max_len`.

    `k`/`v`: [(L,) n_pages, page_size, H, D] — a page pool shared by every
    lane. `page_table`: [(L,) B, P_max] int32, position-ordered: entry j of
    lane b names the pool page holding that lane's tokens
    [j*page_size, (j+1)*page_size). The sentinel id `n_pages` (one PAST the
    pool) marks an unmapped entry — writes routed through it fall out of
    bounds and are dropped (`mode="drop"`; the sentinel must be positive
    because negative indices would wrap) and gathers mask it to an invalid
    position. `length`: [(L,) B] filled tokens per lane, same semantics as
    KVCache.length.

    Which pages a lane owns is decided host-side (serve/paging.PagePool)
    at the engine's existing per-chunk sync; the device only ever reads
    the table it was handed, so a lane can never reach another lane's
    pages: its table simply doesn't contain them. Stacked (scanned-layer)
    caches broadcast the same table across the leading L axis so the
    serving layer scan's `dynamic_index_in_dim(leaf, i, 0)` slicing works
    unchanged.
    """
    k: jax.Array
    v: jax.Array
    page_table: jax.Array   # [(L,) B, P_max] int32; n_pages = unmapped
    length: jax.Array       # [(L,) B] int32

    @staticmethod
    def zeros(batch, max_len, n_kv, head_dim, *, n_pages, page_size,
              dtype=jnp.bfloat16, layers: int | None = None):
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"page_size {page_size}")
        p_max = max_len // page_size
        shape = (n_pages, page_size, n_kv, head_dim)
        tshape: tuple[int, ...] = (batch, p_max)
        lshape: tuple[int, ...] = (batch,)
        if layers:
            shape = (layers,) + shape
            tshape = (layers,) + tshape
            lshape = (layers, batch)
        return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                            jnp.full(tshape, n_pages, jnp.int32),
                            jnp.zeros(lshape, jnp.int32))

    @property
    def n_pages(self) -> int:
        return self.k.shape[-4]

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]

    def append(self, k_new, v_new):
        """Decode-step write: [B, 1, H, D] lands at per-lane position
        `length` inside the page the table maps it to. A lane whose
        position runs past its mapped pages (an empty slot, or a
        mid-chunk-dead lane decoding inertly) resolves to the sentinel
        page and the write is dropped — never another lane's memory."""
        ps = self.page_size
        idx = self.length                             # [B]
        page = jnp.take_along_axis(
            self.page_table, (idx // ps)[:, None], axis=1,
            mode="fill", fill_value=self.n_pages)[:, 0]   # [B]
        slot = idx % ps
        k = self.k.at[page, slot].set(k_new[:, 0].astype(self.k.dtype),
                                      mode="drop")
        v = self.v.at[page, slot].set(v_new[:, 0].astype(self.v.dtype),
                                      mode="drop")
        return PagedKVCache(k, v, self.page_table, idx + k_new.shape[1])

    def flat_view(self):
        """Gather-by-page-table: dense [B, P_max*page_size, H, D] views of
        k/v plus absolute positions [B, P_max*page_size] (-1 on unmapped
        pages and past-length slots, the decode_attention mask contract).
        The gathered view is position-ordered, so downstream attention is
        bit-identical to the dense KVCache path."""
        pt = self.page_table                          # [B, P]
        B, P = pt.shape
        ps = self.page_size
        safe = jnp.minimum(pt, self.n_pages - 1)
        k = self.k[safe].reshape(B, P * ps, *self.k.shape[-2:])
        v = self.v[safe].reshape(B, P * ps, *self.v.shape[-2:])
        t = jnp.arange(P * ps)[None, :]
        mapped = jnp.repeat(pt < self.n_pages, ps, axis=1)
        k_pos = jnp.where(mapped & (t < self.length[:, None]), t, -1)
        return k, v, k_pos

    def scatter_prefill(self, lane, dest_pages, slot_ids, true_lens):
        """Page-granular scatter of a dense transient prefill cache into
        the pool. `lane` is a KVCache over the full lane batch
        ([(L,) B, S, H, D], S = P_max*page_size); `dest_pages` [B, P_max]
        maps lane g's page j to a pool page (sentinel entries — pad lanes,
        pages past the prompt — drop). `slot_ids` [B] routes lane g's true
        length to its engine slot (negative = pad lane, dropped). Garbage
        past a lane's true length inside its last mapped page is masked by
        `length` at gather time and overwritten by decode appends."""
        ps = self.page_size
        P = self.page_table.shape[-1]

        def put(pool, lk):
            shp = lk.shape
            lk = lk.reshape(shp[:-3] + (P, ps) + shp[-2:]).astype(pool.dtype)
            if pool.ndim == 5:            # stacked [L, n_pages, ps, H, D]
                return pool.at[:, dest_pages].set(lk, mode="drop")
            return pool.at[dest_pages].set(lk, mode="drop")

        n_slots = self.length.shape[-1]
        safe_slot = jnp.where(slot_ids >= 0, slot_ids, jnp.int32(n_slots))
        tl = true_lens.astype(self.length.dtype)
        if self.length.ndim == 2:                     # stacked [L, B]
            length = self.length.at[:, safe_slot].set(tl[None, :],
                                                      mode="drop")
        else:
            length = self.length.at[safe_slot].set(tl, mode="drop")
        return PagedKVCache(put(self.k, lane.k), put(self.v, lane.v),
                            self.page_table, length)


jax.tree_util.register_dataclass(
    PagedKVCache, data_fields=["k", "v", "page_table", "length"],
    meta_fields=[])


def decode_attention(q, cache_k, cache_v, k_pos, q_pos, *,
                     softmax_scale=None, window: int | None = None):
    """Single-token decode vs a cache. q [B,1,Hq,D]; cache [B,S,Hkv,D];
    k_pos [S] or [B,S] absolute positions (-1 = invalid slot);
    q_pos scalar or [B] (per-lane continuous batching)."""
    B, _, Hq, D = q.shape
    Hkv = cache_k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, 1, Hkv, G, D)
    s = _gqa_scores(qg, cache_k).astype(jnp.float32)       # [B,Hkv,G,1,S]
    k_pos = jnp.broadcast_to(jnp.atleast_2d(k_pos), (B, cache_k.shape[1]))
    q_pos = jnp.broadcast_to(jnp.asarray(q_pos), (B,))[:, None]
    ok = (k_pos >= 0) & (k_pos <= q_pos)
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = _gqa_out(p, cache_v)                             # [B,1,Hkv,G,Dv]
    return out.reshape(B, 1, Hq, cache_v.shape[-1]).astype(q.dtype)
