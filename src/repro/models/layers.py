"""Common layers + the param-schema system.

Every model declares a *schema*: a pytree (nested dicts) of `ParamSpec`s,
each carrying shape, dtype, init style and **logical axis names**. From one
schema we derive three synchronized views:

  * `init_from_schema`    — materialized parameters (random init),
  * `shapes_from_schema`  — jax.ShapeDtypeStruct stand-ins (dry-run: no
                            allocation, exactly the shannon/kernels pattern),
  * `parallel.sharding.pspecs_from_schema` — PartitionSpecs via logical-axis
                            rules with divisibility guards.

Models are pure functions over these param trees (no flax); layer stacks
carry a leading "layers" axis and are scanned with jax.lax.scan so the
lowered HLO is O(1) in depth — essential for compiling 96-layer/340B
configs on the CPU dry-run host.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones
    scale: float | None = None            # stddev; default fan-in
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    scale = spec.scale
    if scale is None:
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_from_schema(rng, schema):
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def shapes_from_schema(schema):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), schema,
        is_leaf=is_spec)


def param_count(schema) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


# --------------------------------------------------------------------------
# primitive layers (pure functions over param dicts)
# --------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


def norm_schema(d: int, kind: str) -> dict:
    if kind == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), init="ones"),
                "bias": ParamSpec((d,), ("embed",), init="zeros")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def apply_norm(p: dict, x, kind: str):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]                 # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def pod_dense(x, w, *, activation: str | None = None):
    """One dense projection on the Pallas systolic pod GEMM.

    Fused-lane execution: every leading axis of x (decode lanes, sequence,
    batch) collapses into the GEMM M axis, so a decode batch's per-lane
    GEMVs run as the ONE fused [lanes, K] @ [K, N] GEMM the tenancy
    co-scheduling analysis assumes. Trailing axes of w beyond the
    contraction fold into N and unfold on return (e.g. [d, H, hd] heads).
    Block geometry comes from the DSE autotuner
    (parallel.autoshard.choose_blocks, per-shape cached); `activation`
    runs in the kernel's fused epilogue (the paper's SIMD post-processor).
    """
    from ..kernels.systolic_gemm.guard import active_guard
    from ..kernels.systolic_gemm.ops import fused_lane_gemm
    k = x.shape[-1]
    out = fused_lane_gemm(x, w.reshape(k, -1), activation=activation,
                          out_dtype=x.dtype, guard=active_guard())
    return out.reshape(x.shape[:-1] + w.shape[1:])


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def mlp_schema(d_model: int, d_ff: int, activation: str,
               layers: int | None = None) -> dict:
    """Gated (GLU) for silu/gelu-glu archs; plain up/down for relu2/gelu."""
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    gated = activation in ("silu",)
    sch = {
        "up": ParamSpec(lead + (d_model, d_ff), lax_ + ("embed", "ff")),
        "down": ParamSpec(lead + (d_ff, d_model), lax_ + ("ff", "embed")),
    }
    if gated:
        sch["gate"] = ParamSpec(lead + (d_model, d_ff), lax_ + ("embed", "ff"))
    return sch


def apply_mlp(p: dict, x, activation: str, use_pallas: bool = False):
    if use_pallas:
        # activation fuses into the GEMM epilogue (no extra HBM round-trip)
        up = pod_dense(x, p["up"],
                       activation=None if "gate" in p else activation)
        if "gate" in p:
            up = pod_dense(x, p["gate"], activation=activation) * up
        return pod_dense(up, p["down"])
    act = activation_fn(activation)
    up = jnp.einsum("...d,df->...f", x, p["up"])
    if "gate" in p:
        up = act(jnp.einsum("...d,df->...f", x, p["gate"])) * up
    else:
        up = act(up)
    return jnp.einsum("...f,fd->...d", up, p["down"])


def embed_schema(vocab: int, d_model: int, tie: bool) -> dict:
    sch = {"tok": ParamSpec((vocab, d_model), ("vocab", "embed"), scale=1.0)}
    if not tie:
        sch["unembed"] = ParamSpec((d_model, vocab), ("embed", "vocab"))
    return sch


def embed(p: dict, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, x, use_pallas: bool = False):
    """Hidden states -> logits: the largest single GEMM of the decode step.
    use_pallas routes it through the pod kernel — untied [d, vocab] weights
    on the fused-lane GEMM, tied embeddings on the transposed-weight
    variant, which streams the stored [vocab, d] token table directly (no
    transpose copy of the embedding in HBM)."""
    if use_pallas:
        from ..kernels.systolic_gemm.guard import active_guard
        from ..kernels.systolic_gemm.ops import (fused_lane_gemm,
                                                 fused_lane_gemm_t)
        g = active_guard()
        if "unembed" in p:
            return fused_lane_gemm(x, p["unembed"], out_dtype=x.dtype,
                                   guard=g)
        return fused_lane_gemm_t(x, p["tok"], out_dtype=x.dtype, guard=g)
    if "unembed" in p:
        return jnp.einsum("...d,dv->...v", x, p["unembed"])
    return jnp.einsum("...d,vd->...v", x, p["tok"])


def cross_entropy_loss(logits, labels, ignore_id: int = -1):
    """Stable CE; logits may be vocab-sharded (XLA reduces across shards)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    loss = (lse - ll) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
