"""Unified model API over the segment system.

    model = Model(get_arch("yi-6b"))
    params = model.init(rng)                       # or shapes() for dry-run
    loss = model.loss(params, batch)               # train
    logits, cache = model.prefill(params, batch)   # serving: prompt
    logits, cache = model.decode_step(params, tok, cache)  # serving: token

Caches, params and batches are plain pytrees; everything composes with jit,
shard_map, grad and the launch/ dry-run (which only ever touches
`model.schema()` shapes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, PagedKVCache, RingKVCache
from .layers import (ParamSpec, apply_norm, cross_entropy_loss, embed,
                     embed_schema, init_from_schema, is_spec, norm_schema,
                     param_count, shapes_from_schema, unembed)
from .ssm import SSMCache
from .transformer import (MLACache, Segment, apply_block, block_schema,
                          segments)

Constrain = Callable[[jax.Array, str], jax.Array]


@dataclasses.dataclass
class CrossKV:
    k: jax.Array   # [B, S_src, KV, hd]
    v: jax.Array

    @staticmethod
    def zeros(batch, src_len, n_kv, head_dim, dtype=jnp.bfloat16,
              layers: int | None = None):
        s = (batch, src_len, n_kv, head_dim)
        if layers:
            s = (layers,) + s
        return CrossKV(jnp.zeros(s, dtype), jnp.zeros(s, dtype))


jax.tree_util.register_dataclass(CrossKV, data_fields=["k", "v"], meta_fields=[])


def _stack_schema(sch, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            init=s.init, scale=s.scale, dtype=s.dtype),
        sch, is_leaf=is_spec)


def _sinusoid(seq: int, d: int, offset=0):
    # offset: scalar or [B] (per-lane decode positions); returns
    # [1 or B, seq, d] broadcasting against [B, seq, d] activations.
    off = jnp.atleast_1d(jnp.asarray(offset))
    pos = (jnp.arange(seq)[None, :] + off[:, None]).astype(jnp.float32)
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos[..., None] / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class Model:
    def __init__(self, cfg: ArchConfig, attention_impl: str = "chunked",
                 ssd_impl: str = "jnp", kv_rep: int = 1,
                 constrain: Constrain | None = None, unroll: bool = False,
                 remat: bool = False, kv_block: int = 1024,
                 use_pallas: bool = False):
        self.cfg = cfg
        self.impl = attention_impl
        self.ssd_impl = ssd_impl
        self.kv_rep = kv_rep
        self.constrain = constrain or (lambda x, kind: x)
        # use_pallas routes dense/GQA projections + MLPs through the
        # systolic pod GEMM kernel with DSE-autotuned block geometry
        # (kernels/systolic_gemm; interpret mode off-TPU). Reference
        # einsum path stays the default and the numerics oracle.
        self.use_pallas = use_pallas
        # unroll=True replaces lax.scan with a Python loop over indexed
        # layer params — used by the dry-run's L1/L2 flop-calibration
        # compiles (XLA cost analysis counts a while body once; unrolled
        # variants + per-layer extrapolation recover exact totals).
        self.unroll = unroll
        # remat=True checkpoints each layer body: backward keeps only the
        # per-layer residual-stream carries (L x [B,S,D], sequence-sharded
        # under SP) and recomputes within-layer activations — the policy
        # that lets 340B train cells fit 16 GB/chip.
        self.remat = remat
        self.kv_block = kv_block   # chunked-attention block (SOSA DSE knob)
        self.segs = segments(cfg)

    def _body(self, fn):
        """Wrap a scan body with per-layer remat when training."""
        return jax.checkpoint(fn) if self.remat else fn

    def _scan(self, body, carry, xs):
        # an active PodGuard tape accumulates per-GEMM flags as traced
        # values on Python state — under lax.scan those would leak out of
        # the scan body, so a taped trace takes the unrolled path (guard
        # engines trade compile time for per-layer checksum visibility;
        # untaped traces keep the seed scan and its jit cache exactly)
        from ..kernels.systolic_gemm.guard import active_tape
        if not self.unroll and active_tape() is None:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            xi = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, xi)
            ys.append(y)
        stacked = None
        if ys and ys[0] is not None:
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        return carry, stacked

    # -- schema / params ---------------------------------------------------
    def schema(self) -> dict:
        cfg = self.cfg
        sch: dict = {"embed": embed_schema(cfg.vocab, cfg.d_model,
                                           cfg.tie_embeddings),
                     "ln_f": norm_schema(cfg.d_model, cfg.norm)}
        for seg in self.segs:
            sch[seg.name] = self._segment_schema(seg)
        if cfg.encoder_decoder:
            sch["encoder"] = {
                "blocks": block_schema(cfg, "encoder", cfg.n_encoder_layers),
                "ln_f": norm_schema(cfg.d_model, cfg.norm),
            }
        if cfg.family == "vlm":
            sch["img_adapter"] = ParamSpec(
                (cfg.d_model, cfg.d_model), ("embed", None))
        return sch

    def _segment_schema(self, seg: Segment) -> dict:
        cfg = self.cfg
        if seg.kind == "vlm":
            inner = cfg.cross_attn_every - 1
            return {
                "plain": _stack_schema(block_schema(cfg, "dense", inner), seg.n),
                "cross": block_schema(cfg, "cross_layer", seg.n),
            }
        return block_schema(cfg, seg.kind, seg.n if seg.n > 1 else None)

    def init(self, rng) -> dict:
        return init_from_schema(rng, self.schema())

    def shapes(self) -> dict:
        return shapes_from_schema(self.schema())

    def param_count(self) -> int:
        return param_count(self.schema())

    # -- forward -----------------------------------------------------------
    def _embed_in(self, params, batch, offset=0):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"])
        if not cfg.use_rope and cfg.family != "ssm":
            x = x + _sinusoid(x.shape[1], cfg.d_model,
                              offset=offset).astype(x.dtype)
        return self.constrain(x, "residual")

    def _encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)
        x = self.constrain(x, "residual")
        pos = jnp.arange(frames.shape[1])

        def body(carry, p_layer):
            h, _ = apply_block(p_layer, carry, cfg, "encoder", positions=pos,
                               impl=self.impl, causal=False,
                               use_pallas=self.use_pallas)
            return self.constrain(h, "residual"), None

        x, _ = self._scan(self._body(body), x, params["encoder"]["blocks"])
        return apply_norm(params["encoder"]["ln_f"], x, cfg.norm)

    def _cross_source(self, params, batch):
        cfg = self.cfg
        if cfg.encoder_decoder:
            return self._encode(params, batch["frames"])
        if cfg.family == "vlm":
            return jnp.einsum("bnd,de->bne", batch["image_embeds"],
                              params["img_adapter"])
        return None

    def _run_segment(self, seg: Segment, p_seg, x, positions, cache_seg,
                     cross_src, true_lens=None):
        cfg = self.cfg
        kw = dict(positions=positions, impl=self.impl, ssd_impl=self.ssd_impl,
                  kv_rep=self.kv_rep, window=seg.window,
                  kv_block=self.kv_block, constrain=self.constrain,
                  use_pallas=self.use_pallas, true_lens=true_lens)

        if seg.kind == "vlm":
            return self._run_vlm_segment(seg, p_seg, x, cache_seg,
                                         cross_src, kw)

        if seg.n == 1:
            x, nc = apply_block(p_seg, x, cfg, seg.kind, cache=cache_seg,
                                cross_src=cross_src, **kw)
            return self.constrain(x, "residual"), (nc if cache_seg is not None
                                                   else None)

        if cache_seg is None:                     # train/eval: plain scan
            def body(carry, p_layer):
                h, _ = apply_block(p_layer, carry, cfg, seg.kind,
                                   cache=None, cross_src=cross_src, **kw)
                return self.constrain(h, "residual"), None

            x, _ = self._scan(self._body(body), x, p_seg)
            return x, None

        # serving: carry the stacked cache and update layer i in place —
        # XLA reuses the carry buffer across iterations, so the KV cache
        # costs 1x HBM instead of the 2-3x an xs->ys scan would copy.
        def body(carry, xs):
            h, cache_st = carry
            p_layer, i = xs
            cache_l = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False),
                cache_st)
            h, nc = apply_block(p_layer, h, cfg, seg.kind, cache=cache_l,
                                cross_src=cross_src, **kw)
            cache_st = jax.tree.map(
                lambda a, nv: jax.lax.dynamic_update_index_in_dim(
                    a, nv.astype(a.dtype), i, 0),
                cache_st, nc)
            return (self.constrain(h, "residual"), cache_st), None

        (x, new_cache), _ = self._scan(
            body, (x, cache_seg), (p_seg, jnp.arange(seg.n)))
        return x, new_cache

    def _run_vlm_segment(self, seg, p_seg, x, cache_seg, cross_src, kw):
        cfg = self.cfg

        if cache_seg is None:
            def group(carry, p_g):
                def inner(c2, p_l):
                    h2, _ = apply_block(p_l, c2, cfg, "dense", cache=None,
                                        **kw)
                    return self.constrain(h2, "residual"), None

                h, _ = self._scan(inner, carry, p_g["plain"])
                h, _ = apply_block(p_g["cross"], h, cfg, "cross_layer",
                                   cache=None, cross_src=cross_src, **kw)
                return self.constrain(h, "residual"), None

            x, _ = self._scan(self._body(group), x, p_seg)
            return x, None

        inner_n = cfg.cross_attn_every - 1

        def group(carry, xs):
            h, cache_st = carry
            p_g, gi = xs

            def inner(c2, xs2):
                h2, plain_st = c2
                p_l, li = xs2
                cache_l = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        jax.lax.dynamic_index_in_dim(a, gi, 0,
                                                     keepdims=False),
                        li, 0, keepdims=False),
                    plain_st)
                h2, nc = apply_block(p_l, h2, cfg, "dense", cache=cache_l,
                                     **kw)
                plain_st = jax.tree.map(
                    lambda a, nv: jax.lax.dynamic_update_index_in_dim(
                        a, jax.lax.dynamic_update_index_in_dim(
                            jax.lax.dynamic_index_in_dim(
                                a, gi, 0, keepdims=False),
                            nv.astype(a.dtype), li, 0),
                        gi, 0),
                    plain_st, nc)
                return (self.constrain(h2, "residual"), plain_st), None

            (h, plain_st), _ = self._scan(
                inner, (h, cache_st["plain"]),
                (p_g["plain"], jnp.arange(inner_n)))
            cross_l = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, gi, 0,
                                                       keepdims=False),
                cache_st["cross"])
            h, nc_cross = apply_block(p_g["cross"], h, cfg, "cross_layer",
                                      cache=cross_l, cross_src=cross_src,
                                      **kw)
            cross_st = jax.tree.map(
                lambda a, nv: jax.lax.dynamic_update_index_in_dim(
                    a, nv.astype(a.dtype), gi, 0),
                cache_st["cross"], nc_cross)
            return (self.constrain(h, "residual"),
                    {"plain": plain_st, "cross": cross_st}), None

        (x, new_cache), _ = self._scan(
            group, (x, cache_seg), (p_seg, jnp.arange(seg.n)))
        return x, new_cache

    def forward(self, params, batch, cache: dict | None = None,
                positions=None, true_lens=None):
        """Returns (logits, new_cache). cache None -> train/eval forward.
        true_lens [B]: per-lane valid lengths of a right-padded (bucketed)
        prefill — stateful mixers (SSM conv/SSD state, ring KV) apply
        masked state updates so the padding is inert (see apply_ssm /
        apply_gqa); attention-only KV caches ignore it (causal masking +
        the engine's post-prefill length fixup already handle padding)."""
        cfg = self.cfg
        S = batch["tokens"].shape[1]
        if positions is None:
            positions = jnp.arange(S)
        x = self._embed_in(params, batch,
                           offset=positions[..., 0] if S == 1 else 0)
        cross_src = self._cross_source(params, batch) if cache is None or \
            (cache is not None and S > 1) else None

        new_cache: dict = {}
        for seg in self.segs:
            cseg = cache.get(seg.name) if cache is not None else None
            x, nc = self._run_segment(seg, params[seg.name], x, positions,
                                      cseg, cross_src,
                                      true_lens=true_lens)
            if cache is not None:
                new_cache[seg.name] = nc
        x = apply_norm(params["ln_f"], x, cfg.norm)
        logits = unembed(params["embed"], x, use_pallas=self.use_pallas)
        logits = self.constrain(logits, "logits")
        return logits, (new_cache if cache is not None else None)

    # -- training ----------------------------------------------------------
    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        return cross_entropy_loss(logits, batch["labels"])

    # -- serving -----------------------------------------------------------
    @property
    def bucketed_prefill_ok(self) -> bool:
        """True when prefill lanes can be right-padded to a bucket length
        without corrupting serving state: attention-only KV/MLA caches are
        inert under padding (causal masking + the engine's post-prefill
        length fixup), and SSM / ring (sliding-window) caches now take
        masked state updates driven by the engine's per-lane `true_lens`
        (dt-masked SSD recurrence + true-length conv window, per-lane ring
        slot gather — see apply_ssm / apply_gqa), so ssm and hybrid join
        the bucket path. MoE capacity still lets padding tokens displace
        real ones, and encoder-decoder / VLM prompts carry non-token
        modalities — those families prefill exact-length.
        """
        return (self.cfg.family in ("dense", "ssm", "hybrid")
                and not self.cfg.encoder_decoder)

    def init_cache(self, batch: int, max_len: int, src_len: int = 0,
                   dtype=jnp.bfloat16, page_size: int | None = None,
                   kv_pages: int | None = None) -> dict:
        """page_size/kv_pages non-None builds a *paged* cache: every
        global-attention KVCache leaf becomes a PagedKVCache over a shared
        `kv_pages`-page pool (serve/paging.PagePool owns the host-side
        allocation). Ring (sliding-window) caches are already O(window)
        and SSM state is fixed-size per lane — neither has anything to
        page, so they stay lane-resident. Only the bucketed-prefill
        families (dense/ssm/hybrid) support paging: MLA/VLM/cross-decoder
        caches carry per-request shapes the page-granular prefill scatter
        does not cover."""
        cfg = self.cfg
        if (page_size is None) != (kv_pages is None):
            raise ValueError("page_size and kv_pages must be set together")
        if page_size is not None and not self.bucketed_prefill_ok:
            raise ValueError(
                f"paged KV cache requires a bucketed-prefill family "
                f"(dense/ssm/hybrid), not {cfg.family}")
        if page_size is not None and cfg.mla is not None:
            raise ValueError("paged KV cache does not support MLA caches")
        caches: dict = {}
        kv_v = max(1, cfg.n_kv_heads) * self.kv_rep
        hd = cfg.resolved_head_dim

        def kv_zeros(L):
            if page_size is not None:
                return PagedKVCache.zeros(batch, max_len, kv_v, hd,
                                          n_pages=kv_pages,
                                          page_size=page_size, dtype=dtype,
                                          layers=L)
            return KVCache.zeros(batch, max_len, kv_v, hd, dtype, layers=L)
        for seg in self.segs:
            L = seg.n if seg.n > 1 else None
            c: Any
            if seg.kind == "ssm":
                c = {"ssm": SSMCache.zeros(cfg, batch, layers=L, dtype=dtype)}
            elif seg.kind == "hybrid":
                if seg.window is not None:
                    att = RingKVCache.zeros(batch, min(seg.window, max_len),
                                            kv_v, hd, dtype)
                    if L:
                        att = jax.tree.map(
                            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy()
                            if a.ndim else jnp.zeros((L,), a.dtype), att)
                else:
                    att = kv_zeros(L)
                c = {"attn": att,
                     "ssm": SSMCache.zeros(cfg, batch, layers=L, dtype=dtype)}
            elif cfg.mla is not None and seg.kind in ("dense", "moe"):
                c = {"attn": MLACache.zeros(batch, max_len,
                                            cfg.mla.kv_lora_rank,
                                            cfg.mla.qk_rope_head_dim, dtype,
                                            layers=L)}
            elif seg.kind == "vlm":
                inner = cfg.cross_attn_every - 1
                plain = KVCache.zeros(batch, max_len, kv_v, hd, dtype)
                plain = jax.tree.map(
                    lambda a: jnp.zeros((seg.n, inner) + a.shape, a.dtype),
                    plain)
                cross = CrossKV.zeros(batch, src_len or cfg.n_image_tokens,
                                      cfg.n_kv_heads, hd, dtype, layers=seg.n)
                c = {"plain": {"attn": plain}, "cross": {"cross": cross}}
            elif seg.kind == "crossdec":
                c = {"attn": KVCache.zeros(batch, max_len, kv_v, hd, dtype,
                                           layers=L),
                     "cross": CrossKV.zeros(batch, src_len, cfg.n_kv_heads,
                                            hd, dtype, layers=L)}
            else:
                c = {"attn": kv_zeros(L)}
            caches[seg.name] = c
        return caches

    def prefill(self, params, batch, cache: dict):
        """Run the prompt through the model, filling `cache`.
        Returns (last-position logits [B, vocab], cache)."""
        logits, cache = self.forward(params, batch, cache=cache)
        return logits[:, -1, :], cache

    def decode_step(self, params, tokens, cache: dict, position):
        """tokens [B] or [B,1]; position: scalar index, or [B] per-lane
        indices (continuous batching with mixed-length requests)."""
        if tokens.ndim == 1:
            tokens = tokens[:, None]
        B = tokens.shape[0]
        pos_vec = jnp.broadcast_to(
            jnp.asarray(position, jnp.int32), (B,))
        positions = pos_vec[:, None]                     # [B, 1]
        logits, cache = self.forward(params, {"tokens": tokens}, cache=cache,
                                     positions=positions)
        return logits[:, -1, :], cache


