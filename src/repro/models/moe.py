"""Mixture-of-Experts: top-k token-choice routing with capacity-based
one-hot dispatch (Switch/GShard style) — the einsum formulation whose
contractions XLA shards into expert-parallel all-to-alls when experts are
placed on the `model` mesh axis (see parallel/sharding.py).

Supports DeepSeek-V2 (160 routed top-6 + 2 shared experts, first layer
dense) and DBRX (16 routed top-4).

Serving hot path (`apply_moe(..., use_pallas=True)`): capacity-bucketed
scatter dispatch + the grouped systolic pod GEMM — every expert is one
group of a single kernel launch, so the decode step's expert FFNs run as
the E-pod co-schedule the SOSA multi-tenancy analysis assumes instead of
a fan of einsums. The einsum paths stay the numerics oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from .layers import ParamSpec, activation_fn, pod_dense


def moe_schema(cfg: ArchConfig, layers: int | None = None) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    sch = {
        "router": ParamSpec(lead + (d, e), lax_ + ("embed", None),
                            dtype=jnp.float32),
        "up": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", "expert_ff")),
        "gate": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", "expert_ff")),
        "down": ParamSpec(lead + (e, f, d), lax_ + ("experts", "expert_ff", "embed")),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        sch["shared_up"] = ParamSpec(lead + (d, fs), lax_ + ("embed", "ff"))
        sch["shared_gate"] = ParamSpec(lead + (d, fs), lax_ + ("embed", "ff"))
        sch["shared_down"] = ParamSpec(lead + (fs, d), lax_ + ("ff", "embed"))
    return sch


def _group_shape(n_tokens: int, group_size: int) -> tuple[int, int]:
    """(groups, tokens_per_group) with groups * tpg == n_tokens."""
    g = max(1, n_tokens // group_size)
    while n_tokens % g:
        g -= 1
    return g, n_tokens // g


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    cap = int(tokens_per_group * m.top_k / m.num_experts * m.capacity_factor)
    return max(1, min(tokens_per_group, cap))


def _route(p, xt, m: MoEConfig, use_sort: bool | None = None):
    """Shared router: (gate_vals, expert_idx, pos, keep) per [G, n, K].
    Priority order for capacity is flat (token-major) order in the group —
    identical between the onehot and sort dispatch paths. `use_sort`
    overrides the config's position computation (the pallas hot path must
    never build the one-hot cumsum, whatever m.dispatch says).

    Position computation:
      onehot — cumsum over a [G, n·K, E] one-hot: O(N·K·E) int traffic.
               At deepseek-v2 train scale that one-hot alone is ~3.8 TB —
               measured as the dominant HBM-bytes term (§Perf iter 1).
      sort   — stable argsort of expert ids + first-occurrence subtraction:
               O(N·K·log) with no E-sized tensors. Same priority order
               (stable sort keeps flat order within an expert), verified
               bit-equal in tests/test_moe.py.
    """
    G, n, _ = xt.shape
    rdt = jnp.float32 if m.router_dtype == "float32" else jnp.bfloat16
    logits = jnp.einsum("gnd,de->gne", xt.astype(rdt),
                        p["router"].astype(rdt))
    probs = jax.nn.softmax(logits.astype(rdt), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)     # [G, n, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize
    cap = _capacity(n, m)

    if use_sort is None:
        use_sort = m.dispatch in ("sort", "hybrid")
    if use_sort:
        nK = n * m.top_k
        flat_e = expert_idx.reshape(G, nK)
        order = jnp.argsort(flat_e, axis=1, stable=True)      # [G, nK]
        sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
        first = jax.vmap(
            lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
        pos_sorted = jnp.arange(nK)[None, :] - first
        # scatter positions back to original (token, k) order
        pos_flat = jax.vmap(
            lambda ps, o: jnp.zeros((nK,), ps.dtype).at[o].set(ps))(
            pos_sorted, order)
        pos = pos_flat.reshape(G, n, m.top_k)
    else:
        onehot = jax.nn.one_hot(expert_idx, m.num_experts,
                                dtype=jnp.int32)              # [G,n,K,E]
        flat = onehot.reshape(G, n * m.top_k, m.num_experts)
        pos = ((jnp.cumsum(flat, axis=1).reshape(onehot.shape) - onehot)
               * onehot).sum(-1)                              # [G, n, K]
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    return gate_vals, expert_idx, pos, keep, cap


def _experts(p, xe, act, constrain=None):
    """xe [G,E,C,D] -> ye [G,E,C,D] (the EP-sharded expert FFNs).
    The constraints pin the EP all-to-all at the dispatch boundary."""
    if constrain is not None:
        xe = constrain(xe, "moe_dispatched")
    h = jnp.einsum("gecd,edf->gecf", xe, p["up"])
    g = act(jnp.einsum("gecd,edf->gecf", xe, p["gate"]))
    ye = jnp.einsum("gecf,efd->gecd", h * g, p["down"])
    if constrain is not None:
        ye = constrain(ye, "moe_dispatched")
    return ye


def _experts_grouped(p, xe, activation: str, constrain=None):
    """xe [G,E,C,D] -> ye [G,E,C,D] on the grouped pod GEMM.

    Experts are the kernel's group axis and each expert's G*C capacity
    rows fuse into its M axis: E independent (G·C x D x F) GEMMs execute
    as ONE kernel launch per projection
    (kernels/systolic_gemm.grouped_systolic_gemm_pallas), with the gate
    activation running in the per-group fused epilogue — the paper's SIMD
    post-processor, one per expert pod."""
    G, E, C, D = xe.shape
    if constrain is not None:
        xe = constrain(xe, "moe_dispatched")
    from ..kernels.systolic_gemm.ops import grouped_gemm
    # the kernel contracts like-typed operands (einsum would promote)
    dt = jnp.promote_types(xe.dtype, p["up"].dtype)
    xg = xe.transpose(1, 0, 2, 3).reshape(E, G * C, D).astype(dt)
    h = grouped_gemm(xg, p["up"].astype(dt), out_dtype=dt)
    g = grouped_gemm(xg, p["gate"].astype(dt), activation=activation,
                     out_dtype=dt)
    ye = grouped_gemm(h * g, p["down"].astype(dt), out_dtype=dt)
    ye = ye.reshape(E, G, C, D).transpose(1, 0, 2, 3)
    if constrain is not None:
        ye = constrain(ye, "moe_dispatched")
    return ye


def apply_moe(p: dict, x, cfg: ArchConfig, constrain=None,
              use_pallas: bool = False):
    """x: [B, S, D] -> [B, S, D].

    GShard-style *grouped* top-k routing: tokens are cut into groups of
    ~group_size with per-group expert capacity. Groups follow the
    (batch, seq) order, so their sharding follows the batch sharding and
    the expert einsums reshard [G,n,·] -> [E,·] as the EP all-to-all.
    Over-capacity tokens drop to the shared-experts/residual path.

    Two dispatch strategies (MoEConfig.dispatch), numerically identical:
      onehot — einsum with [G,n,E,cap] one-hots (reference, GShard)
      sort   — argsort + scatter/gather: O(N·K·D) data movement instead of
               O(N·E·cap·D); the §Perf winner for many-expert models.

    use_pallas forces the sort-style scatter dispatch (capacity-bucketed
    per-expert groups, no one-hot einsums on the hot path) and runs the
    expert FFNs + shared experts on the systolic pod GEMM kernels
    (`_experts_grouped` / layers.pod_dense); the einsum paths above stay
    the numerics oracle. The router logits stay a [·, d]x[d, E] einsum —
    routing, not dispatch, and E columns round below one MXU lane tile.
    """
    m = cfg.moe
    act = activation_fn(cfg.activation)
    B, S, D = x.shape
    N = B * S
    G, n = _group_shape(N, m.group_size)
    xt = x.reshape(G, n, D)
    gate_vals, expert_idx, pos, keep, cap = _route(
        p, xt, m,
        use_sort=True if use_pallas else None)

    if use_pallas or m.dispatch == "sort":
        out = _dispatch_sort(p, xt, gate_vals, expert_idx, pos, keep, cap,
                             cfg, act, use_pallas=use_pallas,
                             constrain=constrain)
    else:
        # "onehot" and "hybrid" (argsort positions + einsum dispatch):
        expert_oh = jax.nn.one_hot(expert_idx, m.num_experts, dtype=x.dtype)
        slot_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                                 dtype=x.dtype)[..., :cap]    # [G,n,K,C]
        dispatch = jnp.einsum("gnke,gnkc->gnec", expert_oh, slot_oh)
        combine = jnp.einsum("gnke,gnkc,gnk->gnec", expert_oh, slot_oh,
                             gate_vals.astype(x.dtype))
        xe = jnp.einsum("gnec,gnd->gecd", dispatch, xt)       # [G,E,C,D]
        ye = _experts(p, xe, act, constrain)
        out = jnp.einsum("gnec,gecd->gnd", combine, ye)

    if m.num_shared_experts:
        if use_pallas:
            h = pod_dense(xt, p["shared_up"])
            g = pod_dense(xt, p["shared_gate"], activation=cfg.activation)
            out = out + pod_dense(h * g, p["shared_down"])
        else:
            h = jnp.einsum("gnd,df->gnf", xt, p["shared_up"])
            g = act(jnp.einsum("gnd,df->gnf", xt, p["shared_gate"]))
            out = out + jnp.einsum("gnf,fd->gnd", h * g, p["shared_down"])
    return out.reshape(B, S, D).astype(x.dtype)


def _dispatch_sort(p, xt, gate_vals, expert_idx, pos, keep, cap, cfg, act,
                   use_pallas: bool = False, constrain=None):
    """argsort/scatter dispatch: same (expert, slot) assignment as the
    one-hot path, but built by indexing instead of dense one-hot einsums.
    With use_pallas the capacity buckets run on the grouped pod GEMM."""
    m = cfg.moe
    G, n, D = xt.shape
    K = m.top_k
    E = m.num_experts
    nK = n * K
    flat_e = expert_idx.reshape(G, nK)
    flat_pos = pos.reshape(G, nK)
    flat_keep = keep.reshape(G, nK)
    # target row in the per-group expert buffer; dropped -> dump row E*cap
    slot = jnp.where(flat_keep, flat_e * cap + flat_pos, E * cap)  # [G,nK]
    tok = jnp.broadcast_to(jnp.arange(n)[:, None], (n, K)).reshape(nK)
    gathered = jnp.take_along_axis(
        xt, jnp.broadcast_to(tok[None, :, None], (G, nK, 1)), axis=1)
    buf = jnp.zeros((G, E * cap + 1, D), xt.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, gathered)
    xe = buf[:, :E * cap].reshape(G, E, cap, D)

    if use_pallas:
        ye = _experts_grouped(p, xe, cfg.activation, constrain)
    else:
        ye = _experts(p, xe, act, constrain)

    ye_flat = ye.reshape(G, E * cap, D)
    back = jnp.take_along_axis(
        ye_flat, jnp.broadcast_to(
            jnp.minimum(slot, E * cap - 1)[..., None], (G, nK, D)), axis=1)
    w = (gate_vals.reshape(G, nK) * flat_keep).astype(xt.dtype)
    out = (back * w[..., None]).reshape(G, n, K, D).sum(axis=2)
    return out


def load_balance_loss(logits, expert_idx, num_experts: int):
    """Auxiliary load-balancing loss (Switch eq. 4)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], num_experts, dtype=jnp.float32),
        axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(density * density_proxy)
