"""Mamba-2 SSD (state-space duality) blocks — sub-quadratic token mixing.

Chunked SSD (the paper's Listing 1, in JAX): within a chunk of length C the
output is a masked matrix product (the "duality" — it is literally a batch
of small GEMMs, which is why SOSA's tiling applies to SSM archs, DESIGN.md
§4); across chunks a lax.scan carries the [H, P, N] state. Total cost
O(S·C) instead of O(S²).

Decode is the recurrent form: h <- exp(dt·A)·h + dt·B·x (O(1) per token),
so mamba2/hymba run the long_500k cell where full-attention archs cannot.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import ParamSpec


def ssm_schema(cfg: ArchConfig, layers: int | None = None) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    G, N, K = s.n_groups, s.d_state, s.conv_kernel
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    conv_dim = di + 2 * G * N
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": ParamSpec(lead + (d, 2 * di + 2 * G * N + H),
                             la + ("embed", "ssm_inner")),
        "conv_w": ParamSpec(lead + (K, conv_dim), la + (None, "ssm_inner")),
        "conv_b": ParamSpec(lead + (conv_dim,), la + ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec(lead + (H,), la + ("ssm_heads",), init="zeros"),
        "D": ParamSpec(lead + (H,), la + ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec(lead + (H,), la + ("ssm_heads",), init="zeros"),
        "norm": ParamSpec(lead + (di,), la + ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec(lead + (di, d), la + ("ssm_inner", "embed")),
    }


def _split_proj(zxbcdt, cfg: ArchConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    G, N = s.n_groups, s.d_state
    H = s.n_heads(cfg.d_model)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, b, cache=None, true_lens=None):
    """Depthwise causal conv1d. x [B,S,Cd], w [K,Cd].
    cache: [B, K-1, Cd] trailing context for decode; returns (y, new_cache).
    true_lens [B]: per-lane valid length of a right-padded prefill — the
    returned context window then ends at each lane's *true* last token
    (ctx index L maps to input position L-(K-1)), bit-identical to what an
    exact-length prefill of that lane would have cached."""
    K = w.shape[0]
    if cache is None:
        ctx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([cache, x], axis=1)
    # y[t] = sum_k w[k] * ctx[t + k]
    S = x.shape[1]
    y = sum(ctx[:, k:k + S, :] * w[k] for k in range(K)) + b
    if K == 1:
        new_cache = ctx[:, :0, :]
    elif true_lens is not None:
        new_cache = jax.vmap(
            lambda c, l: jax.lax.dynamic_slice_in_dim(c, l, K - 1, axis=0)
        )(ctx, true_lens)
    else:
        new_cache = ctx[:, -(K - 1):, :]
    return y, new_cache


def ssd_chunked(x, dt, A, B, C, D, chunk: int, impl: str = "jnp"):
    """SSD forward. x [b,S,H,P]; dt [b,S,H]; A [H] (negative); B,C [b,S,G,N].
    Returns y [b,S,H,P] and final state [b,H,P,N]."""
    if impl == "pallas":
        from repro.kernels.ssd import ops as ssd_ops
        return ssd_ops.ssd(x, dt, A, B, C, D, chunk=chunk)
    return ssd_reference(x, dt, A, B, C, D, chunk)


def ssd_reference(x, dt, A, B, C, D, chunk: int):
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = nc * chunk

    # broadcast groups to heads (G divides H)
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)   # [b,L,H,N]
    Ch = jnp.repeat(C, rep, axis=2)

    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Bc = Bh.reshape(b, nc, chunk, H, N)
    Cc = Ch.reshape(b, nc, chunk, H, N)

    dA = dtc * A[None, None, None, :]                  # [b,nc,c,H] (<0)
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # intra-chunk: Y_intra[t] = sum_{s<=t} C_t.B_s exp(cum_t - cum_s) dt_s x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [b,nc,t,s,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bqthn,bqshn->bqtsh", Cc, Bc).astype(jnp.float32)
    M = scores * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bqtsh,bqshp->bqthp", M.astype(x.dtype), xc)

    # chunk states: S_q = sum_s exp(cum_end - cum_s) dt_s B_s x_s^T
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)       # [b,nc,c,H]
    states = jnp.einsum("bqsh,bqshn,bqshp->bqhpn",
                        (decay_end * dtc).astype(x.dtype), Bc, xc)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # [b,nc,H]

    def step(h, inp):
        st, dec = inp                                  # [b,H,P,N], [b,H]
        h_new = h * dec[:, :, None, None].astype(h.dtype) + st
        return h_new, h

    h0 = jnp.zeros((b, H, P, N), x.dtype)
    h_final, h_prev = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)           # [b,nc,H,P,N]

    # contribution of carried state: Y_inter[t] = C_t exp(cum_t) h_prev
    y_inter = jnp.einsum("bqth,bqthn,bqhpn->bqthp",
                         jnp.exp(cum).astype(x.dtype), Cc, h_prev)
    y = (y_intra + y_inter).reshape(b, L, H, P)[:, :S]
    y = y + x.reshape(b, L, H, P)[:, :S] * D[None, None, :, None]
    return y, h_final


def ssd_decode_step(x, dt, A, B, C, D, h):
    """One-token recurrence. x [b,H,P]; dt [b,H]; B,C [b,G,N]; h [b,H,P,N]."""
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1)   # [b,H,N]
    Ch = jnp.repeat(C, rep, axis=1)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])[..., None, None].astype(h.dtype)
    h_new = h * dA + jnp.einsum("bH,bHn,bHp->bHpn",
                                dtf.astype(x.dtype), Bh, x)
    y = jnp.einsum("bHn,bHpn->bHp", Ch, h_new) + x * D[None, :, None]
    return y, h_new


@dataclasses.dataclass
class SSMCache:
    """Decode state: conv context + SSD state (optionally layer-stacked)."""
    conv: jax.Array    # [(L,) B, K-1, conv_dim]
    state: jax.Array   # [(L,) B, H, P, N]

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int, layers: int | None = None,
              dtype=jnp.bfloat16):
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        H = s.n_heads(cfg.d_model)
        conv_dim = di + 2 * s.n_groups * s.d_state
        cshape = (batch, s.conv_kernel - 1, conv_dim)
        sshape = (batch, H, s.head_dim, s.d_state)
        if layers:
            cshape = (layers,) + cshape
            sshape = (layers,) + sshape
        return SSMCache(jnp.zeros(cshape, dtype), jnp.zeros(sshape, dtype))

    def lane_bytes(self) -> int:
        """Device bytes of ONE lane's SSM state (conv window + SSD state).
        The state is fixed-size regardless of context length — there is
        nothing for the paged KV pool to page, so paged serving keeps SSM
        state lane-resident and the memory accounting
        (ServeEngine.paged_kv_stats) reports it separately and honestly."""
        batch = self.conv.shape[-3]
        return (self.conv.size * self.conv.dtype.itemsize
                + self.state.size * self.state.dtype.itemsize) // batch


jax.tree_util.register_dataclass(
    SSMCache, data_fields=["conv", "state"], meta_fields=[])


def apply_ssm(p: dict, u, cfg: ArchConfig, cache: SSMCache | None = None,
              impl: str = "jnp", true_lens=None):
    """Full Mamba-2 mixer. u [B,S,D] -> ([B,S,D], new_cache_or_None).

    Prefill/train: chunked SSD (cache may be None). When S == 1 and a cache
    is provided, takes the O(1) recurrent path.

    true_lens [B] (bucketed prefill): the input is right-padded to a shared
    bucket length and the recurrence must not integrate the padding. The
    masked state update is dt <- dt * (pos < L): a padded step then has
    exp(dt·A) = 1 and dt·B·x = 0 — an exact identity on the SSD state —
    and contributes exactly zero to every real position's intra-chunk
    output, so real-lane outputs and the final state are bit-identical to
    an exact-length prefill. The conv context window is gathered at the
    true length (`_causal_conv`).
    """
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    P = s.head_dim
    G, N = s.n_groups, s.d_state

    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, x, B, C, dt = _split_proj(zxbcdt, cfg)
    xBC = jnp.concatenate([x, B, C], axis=-1)
    conv_cache = cache.conv if cache is not None else None
    if true_lens is not None and u.shape[1] == 1:
        true_lens = None                        # decode: nothing is padded
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_cache,
                                 true_lens=true_lens)
    xBC = jax.nn.silu(xBC)
    x, B, C = jnp.split(xBC, [di, di + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if true_lens is not None:
        valid = jnp.arange(u.shape[1])[None, :] < true_lens[:, None]
        dt = dt * valid[..., None]              # exact 0 at padded steps
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    bsz, S = u.shape[0], u.shape[1]
    xh = x.reshape(bsz, S, H, P)
    Bh = B.reshape(bsz, S, G, N)
    Ch = C.reshape(bsz, S, G, N)

    if cache is not None and S == 1:
        y, h_new = ssd_decode_step(
            xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0], p["D"], cache.state)
        y = y[:, None]
    else:
        y, h_new = ssd_chunked(xh, dt, A, Bh, Ch, p["D"], s.chunk_size, impl)

    y = y.reshape(bsz, S, di)
    # gated RMSNorm (Mamba-2)
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6)).astype(u.dtype) * p["norm"]
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = SSMCache(new_conv, h_new) if cache is not None else None
    return out, new_cache
