"""Model assembly: blocks, segments and stacks for the 10 assigned archs.

A model is a list of *segments*; each segment is a homogeneous stack of
layers scanned with jax.lax.scan (params carry a leading `layers` axis), so
HLO size is O(#segments), not O(depth). Heterogeneity is expressed between
segments:

  dense LMs           [("layers", dense, L)]
  dbrx                [("moe", moe, L)]
  deepseek-v2         [("dense0", dense-mla, 1), ("moe", moe-mla, L-1)]
  mamba2              [("layers", ssm, L)]
  hymba               global-attn layers split the SWA stack:
                      [g0 | swa x14 | g15 | swa x15 | g31], all hybrid blocks
  llama-3.2-vision    [("blocks", vlm 5-layer group, L/5)] (4 dense + 1 cross)
  whisper             encoder [("enc", encoder, L)] + decoder
                      [("dec", cross-decoder, L)]

Biases are omitted throughout (weights dominate; noted in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from .attention import (KVCache, PagedKVCache, RingKVCache, chunked_attention,
                        decode_attention)
from .layers import (ParamSpec, apply_mlp, apply_norm, apply_rope, embed,
                     mlp_schema, norm_schema, pod_dense, unembed,
                     embed_schema)
from .moe import apply_moe, moe_schema
from .ssm import SSMCache, apply_ssm, ssm_schema

Constrain = Callable[[jax.Array, str], jax.Array]
_id_constrain: Constrain = lambda x, kind: x


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kind: str                  # dense | moe | ssm | hybrid | vlm | encoder | crossdec
    n: int                     # number of layers (or groups for vlm)
    window: Optional[int] = None   # sliding window for attention (hybrid)


def segments(cfg: ArchConfig) -> list[Segment]:
    if cfg.family == "vlm":
        assert cfg.n_layers % cfg.cross_attn_every == 0
        return [Segment("blocks", "vlm", cfg.n_layers // cfg.cross_attn_every)]
    if cfg.family == "ssm":
        return [Segment("layers", "ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        segs: list[Segment] = []
        prev = 0
        for gi, g in enumerate(sorted(cfg.global_attn_layers)):
            if g > prev:
                segs.append(Segment(f"swa{gi}", "hybrid", g - prev,
                                    window=cfg.sliding_window))
            segs.append(Segment(f"glob{gi}", "hybrid", 1, window=None))
            prev = g + 1
        if prev < cfg.n_layers:
            segs.append(Segment("swa_tail", "hybrid", cfg.n_layers - prev,
                                window=cfg.sliding_window))
        return segs
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        segs = []
        if fd:
            segs.append(Segment("dense0", "dense", fd))
        segs.append(Segment("moe", "moe", cfg.n_layers - fd))
        return segs
    if cfg.encoder_decoder:
        return [Segment("dec", "crossdec", cfg.n_layers)]
    return [Segment("layers", "dense", cfg.n_layers)]


# --------------------------------------------------------------------------
# attention blocks (GQA and MLA)
# --------------------------------------------------------------------------

def attn_schema(cfg: ArchConfig, layers: int | None) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    if cfg.mla:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "q_a": ParamSpec(lead + (d, m.q_lora_rank), la + ("embed", None)),
            "q_a_norm": ParamSpec(lead + (m.q_lora_rank,), la + (None,), init="ones"),
            "q_b": ParamSpec(lead + (m.q_lora_rank, cfg.n_heads, qk_dim),
                             la + (None, "heads", None)),
            "kv_a": ParamSpec(lead + (d, m.kv_lora_rank + m.qk_rope_head_dim),
                              la + ("embed", None)),
            "kv_a_norm": ParamSpec(lead + (m.kv_lora_rank,), la + (None,), init="ones"),
            "kv_b": ParamSpec(lead + (m.kv_lora_rank, cfg.n_heads,
                                      m.qk_nope_head_dim + m.v_head_dim),
                              la + (None, "heads", None)),
            "o": ParamSpec(lead + (cfg.n_heads, m.v_head_dim, d),
                           la + ("heads", None, "embed")),
        }
    return {
        "q": ParamSpec(lead + (d, cfg.n_heads, hd), la + ("embed", "heads", None)),
        "k": ParamSpec(lead + (d, cfg.n_kv_heads, hd), la + ("embed", "kv_heads", None)),
        "v": ParamSpec(lead + (d, cfg.n_kv_heads, hd), la + ("embed", "kv_heads", None)),
        "o": ParamSpec(lead + (cfg.n_heads, hd, d), la + ("heads", None, "embed")),
    }


def apply_gqa(p, x, cfg: ArchConfig, *, positions, causal=True, window=None,
              impl="chunked", cache: KVCache | RingKVCache | None = None,
              kv_rep: int = 1, kv_x=None, kv_block: int = 1024,
              use_pallas: bool = False, true_lens=None):
    """GQA attention. Train/prefill when cache is None or being filled;
    decode when x has S == 1 and cache is not None.
    kv_x: optional separate KV source (cross-attention).
    use_pallas routes the q/k/v/o projections through the systolic pod
    GEMM (layers.pod_dense, fused-lane form).
    true_lens [B]: per-lane valid length of a right-padded (bucketed)
    prefill — ring caches then gather each lane's last-window *real*
    tokens into their ring slots instead of the padded tail."""
    src = kv_x if kv_x is not None else x
    if use_pallas:
        q = pod_dense(x, p["q"])
        k = pod_dense(src, p["k"])
        v = pod_dense(src, p["v"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["q"])
        k = jnp.einsum("bsd,dhk->bshk", src, p["k"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["v"])
    if cfg.use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if kv_rep > 1:
        k = jnp.repeat(k, kv_rep, axis=2)
        v = jnp.repeat(v, kv_rep, axis=2)

    new_cache = None
    if cache is not None and x.shape[1] == 1:            # decode
        q_pos = positions[..., 0]                        # scalar or [B]
        if isinstance(cache, RingKVCache):
            new_cache = cache.append_token(k, v)
            k_pos = new_cache.positions()                # [B, W]
            out = decode_attention(q, new_cache.k, new_cache.v, k_pos,
                                   q_pos, window=window)
        elif isinstance(cache, PagedKVCache):
            # paged decode: append into the mapped page, then gather the
            # lane's pages back to a position-ordered dense view — same
            # decode_attention contract (k_pos -1 = invalid) as the dense
            # path, so tokens are bit-identical to KVCache serving.
            new_cache = cache.append(k, v)
            ck, cv, k_pos = new_cache.flat_view()
            out = decode_attention(q, ck, cv, k_pos, q_pos, window=window)
        else:
            new_cache = cache.append(k, v)
            ar = jnp.arange(new_cache.k.shape[1])
            k_pos = jnp.where(ar[None, :] < new_cache.length[:, None],
                              ar[None, :], -1)           # [B, S]
            out = decode_attention(q, new_cache.k, new_cache.v, k_pos,
                                   q_pos, window=window)
    else:                                                # train / prefill
        if cache is not None:
            if isinstance(cache, RingKVCache):
                W = cache.window
                S = k.shape[1]
                if true_lens is not None:
                    # bucketed prefill: per-lane gather of the last-window
                    # real tokens into ring layout (token p -> slot p % W).
                    # Slot s holds p(s) = last - ((last - s) mod W), the
                    # newest real position congruent to s; slots older than
                    # the window (or before position 0) stay zero and are
                    # masked by positions() via the true length.
                    last = (true_lens - 1)[:, None]            # [B, 1]
                    slots = jnp.arange(W)[None, :]             # [1, W]
                    pos = last - ((last - slots) % W)          # [B, W]
                    valid = (pos >= 0) & (pos > last - W)
                    idx = jnp.clip(pos, 0, S - 1)[..., None, None]
                    take = lambda a: jnp.where(
                        valid[..., None, None],
                        jnp.take_along_axis(
                            a, jnp.broadcast_to(
                                idx, (a.shape[0], W) + a.shape[2:]), axis=1),
                        0)
                    new_cache = RingKVCache(take(k), take(v),
                                            true_lens.astype(jnp.int32))
                else:
                    # exact-length prefill: keep last `window` tokens
                    kw = k[:, -W:]
                    vw = v[:, -W:]
                    pad = W - kw.shape[1]
                    if pad > 0:
                        kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    # ring layout: token p lives at slot p % W. If S < W
                    # the suffix already sits at its slots; otherwise
                    # rotate so the first kept token (p = S-W) lands on
                    # slot (S-W) % W.
                    roll = (S % W) if S >= W else 0
                    kw = jnp.roll(kw, roll, axis=1)
                    vw = jnp.roll(vw, roll, axis=1)
                    new_cache = RingKVCache(
                        kw, vw, jnp.full((k.shape[0],), S, jnp.int32))
            elif isinstance(cache, PagedKVCache):
                raise TypeError(
                    "PagedKVCache cannot be prefilled in place; prefill "
                    "through a dense transient cache and scatter_prefill "
                    "into the pool (the serve engine does)")
            else:
                new_cache = cache.append(k, v)
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                q_offset=0, kv_block=kv_block) \
            if impl == "chunked" else \
            attn_mod.attention(q, k, v, impl=impl, causal=causal, window=window)
    B, S = x.shape[0], x.shape[1]
    out = out.reshape(B, S, cfg.n_heads, -1)
    if use_pallas:
        o_w = p["o"].reshape(-1, p["o"].shape[-1])       # [(H hd), d]
        return pod_dense(out.reshape(B, S, -1), o_w), new_cache
    return jnp.einsum("bshk,hkd->bsd", out, p["o"]), new_cache


@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array     # [B, S, R]
    k_rope: jax.Array   # [B, S, rope_dim]
    length: jax.Array   # [B] per-lane

    @staticmethod
    def zeros(batch, max_len, kv_lora, rope_dim, dtype=jnp.bfloat16,
              layers: int | None = None):
        s1 = (batch, max_len, kv_lora)
        s2 = (batch, max_len, rope_dim)
        lshape: tuple[int, ...] = (batch,)
        if layers:
            s1, s2 = (layers,) + s1, (layers,) + s2
            lshape = (layers, batch)
        return MLACache(jnp.zeros(s1, dtype), jnp.zeros(s2, dtype),
                        jnp.zeros(lshape, jnp.int32))

    def append(self, c_new, r_new):
        idx = self.length                                # [B]
        upd = jax.vmap(
            lambda buf, new, i: jax.lax.dynamic_update_slice_in_dim(
                buf, new, i, axis=0))
        c = upd(self.c_kv, c_new, idx)
        r = upd(self.k_rope, r_new, idx)
        return MLACache(c, r, idx + c_new.shape[1])


jax.tree_util.register_dataclass(
    MLACache, data_fields=["c_kv", "k_rope", "length"], meta_fields=[])


def apply_mla(p, x, cfg: ArchConfig, *, positions, impl="chunked",
              cache: MLACache | None = None, kv_block: int = 1024):
    """DeepSeek-V2 MLA. Prefill: decompressed K/V + chunked attention.
    Decode: weight-absorbed form over the compressed cache (the latent
    cache is what makes 32k x 128-head decode fit in HBM)."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_dim)

    q_lat = jnp.einsum("bsd,dr->bsr", x, p["q_a"])
    q_lat = _rms(q_lat, p["q_a_norm"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["q_b"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_lat = jnp.einsum("bsd,dr->bsr", x, p["kv_a"])
    c_kv, k_rope = kv_lat[..., :m.kv_lora_rank], kv_lat[..., m.kv_lora_rank:]
    c_kv = _rms(c_kv, p["kv_a_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]

    w_uk = p["kv_b"][..., :m.qk_nope_head_dim]      # [R, H, nope]
    w_uv = p["kv_b"][..., m.qk_nope_head_dim:]      # [R, H, v]

    if cache is not None and S == 1:                # absorbed decode
        new_cache = cache.append(c_kv, k_rope)
        ckv, krope, length = new_cache.c_kv, new_cache.k_rope, new_cache.length
        q_c = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)        # [B,1,H,R]
        s_nope = jnp.einsum("bshr,btr->bhst", q_c, ckv)
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, krope)
        s = (s_nope + s_rope).astype(jnp.float32) * scale       # [B,H,1,T]
        t_pos = jnp.arange(ckv.shape[1])
        s = s + jnp.where(t_pos[None, :] < length[:, None], 0.0,
                          attn_mod.NEG_INF)[:, None, None, :]
        pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx_c = jnp.einsum("bhst,btr->bshr", pr, ckv)           # [B,1,H,R]
        ctx = jnp.einsum("bshr,rhv->bshv", ctx_c, w_uv)
        out = jnp.einsum("bshv,hvd->bsd", ctx, p["o"])
        return out, new_cache

    # prefill / train: decompress K, V per head
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, w_uk)
    v = jnp.einsum("bsr,rhv->bshv", c_kv, w_uv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(qf, k, v, causal=True, softmax_scale=scale,
                            kv_block=kv_block)
    out = jnp.einsum("bshv,hvd->bsd", out, p["o"])
    new_cache = cache.append(c_kv, k_rope) if cache is not None else None
    return out, new_cache


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def block_schema(cfg: ArchConfig, kind: str, layers: int | None) -> dict:
    d = cfg.d_model
    sch: dict = {}
    if kind in ("dense", "moe", "hybrid", "encoder", "crossdec"):
        sch["ln_attn"] = _norms(cfg, d, layers)
        sch["attn"] = attn_schema(cfg, layers)
    if kind in ("dense", "moe", "hybrid", "encoder", "crossdec", "cross_layer"):
        sch["ln_mlp"] = _norms(cfg, d, layers)
        if kind == "moe":
            sch["moe"] = moe_schema(cfg, layers)
        else:
            sch["mlp"] = mlp_schema(d, cfg.d_ff, cfg.activation, layers)
    if kind in ("ssm", "hybrid"):
        sch["ln_ssm"] = _norms(cfg, d, layers)
        sch["ssm"] = ssm_schema(cfg, layers)
    if kind in ("crossdec", "cross_layer"):
        sch["ln_cross"] = _norms(cfg, d, layers)
        sch["cross"] = attn_schema(
            dataclasses.replace(cfg, mla=None), layers)
    return sch


def _norms(cfg: ArchConfig, d: int, layers: int | None) -> dict:
    base = norm_schema(d, cfg.norm)
    if layers:
        return {k: ParamSpec((layers,) + v.shape, ("layers",) + v.axes,
                             init=v.init, dtype=v.dtype)
                for k, v in base.items()}
    return base


def apply_block(p, x, cfg: ArchConfig, kind: str, *,
                positions, window=None, impl="chunked", ssd_impl="jnp",
                cache: dict | None = None, kv_rep: int = 1,
                cross_src=None, causal=True, kv_block: int = 1024,
                constrain=None, use_pallas: bool = False, true_lens=None):
    """One layer. cache: dict with keys subset of {attn, ssm, cross} or None.
    cross_src: source embeddings for cross-attention (encoder output /
    image embeddings); at decode the per-layer cross K/V come from the
    cache instead. Returns (x, new_cache_dict).
    use_pallas: dense/GQA projections, MLPs and the MoE expert dispatch
    (capacity-bucketed grouped pod GEMM, models/moe.py) run on the
    systolic pod kernels (MLA, SSM and the cross-attention q/o stay on
    the reference einsum path)."""
    new_cache: dict = {}

    def _cross_kv():
        """(k, v) for the cross attention + cache bookkeeping."""
        if cache is not None and "cross" in cache and x.shape[1] == 1:
            ck = cache["cross"]
            new_cache["cross"] = ck          # static across decode steps
            return ck.k, ck.v
        assert cross_src is not None, "cross layer needs cross_src"
        k, v = cross_kv_precompute(p["cross"], cross_src, cfg)
        if cache is not None and "cross" in cache:
            from .model import CrossKV
            new_cache["cross"] = CrossKV(k, v)
        return k, v
    if kind == "ssm":
        h = apply_norm(p["ln_ssm"], x, cfg.norm)
        y, sc = apply_ssm(p["ssm"], h, cfg,
                          cache=cache.get("ssm") if cache else None,
                          impl=ssd_impl, true_lens=true_lens)
        if sc is not None:
            new_cache["ssm"] = sc
        return x + y, new_cache

    if kind == "cross_layer":                    # vlm image layer
        h = apply_norm(p["ln_cross"], x, cfg.norm)
        k, v = _cross_kv()
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["q"])
        out = chunked_attention(q, k, v, causal=False)
        a = jnp.einsum("bshk,hkd->bsd",
                       out.reshape(h.shape[0], h.shape[1], cfg.n_heads, -1),
                       p["cross"]["o"])
        x = x + a
        h = apply_norm(p["ln_mlp"], x, cfg.norm)
        return x + apply_mlp(p["mlp"], h, cfg.activation,
                             use_pallas=use_pallas), new_cache

    if kind == "hybrid":
        h = apply_norm(p["ln_attn"], x, cfg.norm)
        a, ac = apply_gqa(p["attn"], h, cfg, positions=positions,
                          causal=causal, window=window, impl=impl,
                          cache=cache.get("attn") if cache else None,
                          kv_rep=kv_rep, use_pallas=use_pallas,
                          true_lens=true_lens)
        s, sc = apply_ssm(p["ssm"], apply_norm(p["ln_ssm"], x, cfg.norm),
                          cfg, cache=cache.get("ssm") if cache else None,
                          impl=ssd_impl, true_lens=true_lens)
        if ac is not None:
            new_cache["attn"] = ac
        if sc is not None:
            new_cache["ssm"] = sc
        x = x + 0.5 * (a + s)
        h = apply_norm(p["ln_mlp"], x, cfg.norm)
        return x + apply_mlp(p["mlp"], h, cfg.activation,
                             use_pallas=use_pallas), new_cache

    # attention blocks (dense / moe / encoder / crossdec)
    h = apply_norm(p["ln_attn"], x, cfg.norm)
    if cfg.mla is not None and kind in ("dense", "moe"):
        a, ac = apply_mla(p["attn"], h, cfg, positions=positions, impl=impl,
                          cache=cache.get("attn") if cache else None,
                          kv_block=kv_block)
    else:
        a, ac = apply_gqa(p["attn"], h, cfg, positions=positions,
                          causal=causal, window=window, impl=impl,
                          cache=cache.get("attn") if cache else None,
                          kv_rep=kv_rep, kv_block=kv_block,
                          use_pallas=use_pallas, true_lens=true_lens)
    if ac is not None:
        new_cache["attn"] = ac
    x = x + a

    if kind == "crossdec":
        h = apply_norm(p["ln_cross"], x, cfg.norm)
        k, v = _cross_kv()
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["q"])
        out = chunked_attention(q, k, v, causal=False)
        a = jnp.einsum("bshk,hkd->bsd",
                       out.reshape(h.shape[0], h.shape[1], cfg.n_heads, -1),
                       p["cross"]["o"])
        x = x + a

    h = apply_norm(p["ln_mlp"], x, cfg.norm)
    if kind == "moe":
        y = apply_moe(p["moe"], h, cfg, constrain=constrain,
                      use_pallas=use_pallas)
    else:
        y = apply_mlp(p["mlp"], h, cfg.activation, use_pallas=use_pallas)
    return x + y, new_cache


def cross_kv_precompute(p_cross, src, cfg: ArchConfig):
    """K/V from an encoder output / image embeddings (no rope)."""
    k = jnp.einsum("bsd,dhk->bshk", src, p_cross["k"])
    v = jnp.einsum("bsd,dhk->bshk", src, p_cross["v"])
    return k, v
