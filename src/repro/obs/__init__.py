"""Serving observability layer: metrics registry, Perfetto trace export,
and model-vs-measured drift tracking.

The paper's headline metric is *effective* throughput/Watt — throughput
adjusted for array utilization — so observability is a first-class
subsystem here, not an afterthought:

  * `obs.metrics`  — labeled counters/gauges/histograms (zero deps), the
    registry `ServeEngine(metrics=...)` and the kernel autotuner report
    into; snapshot/export API.
  * `obs.export`   — spans -> Chrome trace-event / Perfetto JSON, so an
    engine run opens in a trace viewer.
  * `obs.drift`    — per-phase predicted-vs-measured drift rows (wave
    model vs slice-accurate scheduler on the engine's recorded timeline)
    and the live effective-TOPS gauge (measured tokens/s x tile
    utilization).

Every future perf PR is measured against the `obs/` benchmark suite
(benchmarks/obs.py) these build.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,  # noqa: F401
                      registry)
from .export import Span, to_chrome_trace, write_chrome_trace  # noqa: F401

# obs.drift pulls in the tenancy bridge (which itself imports obs.export),
# so its names resolve lazily — importing repro.tenancy.trace first must
# not re-enter a half-initialized obs.drift.
_DRIFT_NAMES = ("DEFAULT_DESIGN", "EffectiveTops", "PhaseDrift",
                "drift_report", "effective_tops_summary")


def __getattr__(name):
    if name in _DRIFT_NAMES:
        from . import drift
        return getattr(drift, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
