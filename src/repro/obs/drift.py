"""Model-vs-measured drift tracking for the serving engine.

The DSE planner (PR 1–2) chooses designs with the analytical wave model
(core.simulator.analyze / analyze_batch); the serving engine executes real
timelines. This module closes the loop per serving phase:

  * `drift_report` lowers the engine's recorded timeline (tenancy/trace.py
    bridge, filtered per phase) and evaluates it through BOTH model paths:
    the wave model (`analyze`, the *predicted* utilization/cycles every
    sweep is built on) and the slice-accurate scheduler (`simulate`, the
    *measured* ground truth with real bank/routing conflicts). The
    per-phase `drift` ratio (predicted/measured utilization) must stay
    inside the calibrated parity bands pinned in tests/test_simulator.py
    (the wave model is up to ~1.55x optimistic on attention-heavy traces)
    — if a future engine change (new fusion shape, new phase structure)
    pushes a serving phase outside the band, the drift row catches it.

  * `effective_tops_summary` is the paper's headline metric, live: the
    engine's measured token throughput (obs metrics counters) converted
    to useful-MAC throughput via the phase's recorded GEMM stream, scaled
    by the kernel autotuner's padded-MAC tile utilization
    (autoshard.tile_utilization gauges) — effective TOPS as SOSA defines
    it (throughput x utilization), directly comparable to the
    `effective_tops_at_tdp` the wave model predicts for the same trace.

Both record their rows as gauges into a metrics registry so the obs/
benchmark suite and live dashboards read one source.
"""

from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig
from ..core.dse import build_accel
from ..core.simulator import OPS_PER_MAC, analyze, simulate
from ..tenancy.trace import ServeTraceRecorder, trace_to_gemms
from .metrics import MetricsRegistry, registry as global_registry

# rows, cols, interconnect, pods — a paper-scale design point (Table 2's
# headline granularity) used when the caller doesn't pin one
DEFAULT_DESIGN = (32, 32, "butterfly-2", 64)

PHASES = ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class PhaseDrift:
    """Predicted (wave model) vs measured (slice-accurate scheduler)
    outcome of one serving phase's recorded GEMM timeline."""

    phase: str
    events: int                      # timeline events lowered
    gemms: int
    predicted_utilization: float     # analyze (wave model)
    measured_utilization: float      # simulate (slice-accurate)
    predicted_cycles: float
    measured_cycles: float
    predicted_effective_tops: float  # @TDP, the DSE ranking metric
    measured_effective_tops: float

    @property
    def drift(self) -> float:
        """Wave-model optimism: predicted / measured utilization. 1.0 =
        perfect agreement; the calibrated ceiling is ~1.55x on
        attention-heavy traces (tests/test_simulator.py)."""
        if not self.measured_utilization:
            return float("inf")
        return self.predicted_utilization / self.measured_utilization


def drift_report(recorder: ServeTraceRecorder, cfg: ArchConfig,
                 design: tuple = DEFAULT_DESIGN, tdp: float = 400.0,
                 max_events_per_phase: int | None = 32,
                 include_attention: bool = True,
                 metrics: MetricsRegistry | None = None
                 ) -> list[PhaseDrift]:
    """Per-phase predicted-vs-measured drift rows for a recorded serving
    run. Phases with no recorded events are skipped (e.g. a prefill-only
    trace). `max_events_per_phase` bounds the slice-accurate scheduler's
    cost on long decode timelines (the drift ratio is a per-phase shape
    property — a bounded prefix measures it)."""
    rows_, cols_, icn, pods = design
    accel = build_accel(rows_, cols_, icn, tdp, pods)
    out: list[PhaseDrift] = []
    for phase in PHASES:
        n_events = sum(1 for e in recorder.events if e[0] == phase)
        if not n_events:
            continue
        gemms = trace_to_gemms(recorder, cfg, kinds=(phase,),
                               include_attention=include_attention,
                               max_events=max_events_per_phase)
        a = analyze(gemms, accel, interconnect=icn)
        s = simulate(gemms, accel, interconnect=icn)
        row = PhaseDrift(
            phase=phase,
            events=min(n_events, max_events_per_phase or n_events),
            gemms=len(gemms),
            predicted_utilization=a.utilization,
            measured_utilization=s.utilization,
            predicted_cycles=float(a.total_cycles),
            measured_cycles=float(s.total_cycles),
            predicted_effective_tops=a.effective_tops_at_tdp,
            measured_effective_tops=s.effective_tops_at_tdp,
        )
        out.append(row)
        # explicit None check: an empty registry is falsy (__len__ == 0)
        # but still the caller's chosen sink
        reg = metrics if metrics is not None else global_registry()
        reg.gauge("obs.drift", phase=phase).set(row.drift)
        reg.gauge("obs.predicted_util", phase=phase).set(
            row.predicted_utilization)
        reg.gauge("obs.measured_util", phase=phase).set(
            row.measured_utilization)
    return out


def _mean_tile_util(reg: MetricsRegistry) -> float:
    """Mean of the kernel autotuner's per-shape padded-MAC utilization
    gauges (1.0 when no kernel shapes were autotuned — e.g. the reference
    einsum backend, whose GEMMs have no pod padding)."""
    gauges = reg.find("autotune.tile_util")
    vals = [g.value for g in gauges.values()]
    return sum(vals) / len(vals) if vals else 1.0


@dataclasses.dataclass(frozen=True)
class EffectiveTops:
    """The live effective-TOPS gauge for one serving phase."""

    phase: str
    tokens: int
    seconds: float
    tok_s: float
    macs_per_token: float          # from the recorded GEMM stream
    tile_utilization: float        # kernel padded-MAC utilization
    measured_tops: float           # useful-MAC throughput, 2 ops/MAC
    effective_tops: float          # measured_tops x tile utilization


def effective_tops_summary(recorder: ServeTraceRecorder, cfg: ArchConfig,
                           metrics: MetricsRegistry,
                           kernel_metrics: MetricsRegistry | None = None,
                           include_attention: bool = True
                           ) -> list[EffectiveTops]:
    """Effective TOPS per serving phase from live telemetry.

    Measured token throughput comes from the engine's obs counters
    (`serve.{prefill,decode}.tokens` / `.seconds` in `metrics`); the
    MACs behind each token come from the recorded GEMM timeline (so fused
    decode lanes and true context lengths are priced exactly); the tile
    utilization comes from the kernel autotuner's gauges (the process-
    global registry unless `kernel_metrics` is passed). Phases without
    recorded time are skipped. Results are recorded back into `metrics`
    as `obs.effective_tops{phase=...}` gauges.
    """
    kreg = kernel_metrics if kernel_metrics is not None else \
        global_registry()
    tile_util = _mean_tile_util(kreg)
    out: list[EffectiveTops] = []
    for phase in PHASES:
        tokens = recorder.phase_tokens(phase)
        seconds = metrics.value(f"serve.{phase}.seconds")
        if not tokens or not seconds:
            continue
        gemms = trace_to_gemms(recorder, cfg, kinds=(phase,),
                               include_attention=include_attention)
        macs = sum(g.macs for g in gemms)
        macs_per_token = macs / tokens
        measured_tops = macs / seconds * OPS_PER_MAC / 1e12
        row = EffectiveTops(
            phase=phase, tokens=tokens, seconds=seconds,
            tok_s=tokens / seconds,
            macs_per_token=macs_per_token,
            tile_utilization=tile_util,
            measured_tops=measured_tops,
            effective_tops=measured_tops * tile_util,
        )
        out.append(row)
        metrics.gauge("obs.effective_tops", phase=phase).set(
            row.effective_tops)
        metrics.gauge("obs.tile_util").set(tile_util)
    return out
