"""Chrome trace-event / Perfetto JSON export of a serving timeline.

`ServeEngine` (serve/engine.py) emits one `Span` per device call — a
prefill launch or a fused decode chunk — into the duck-typed tracer
(`tenancy.ServeTraceRecorder.on_span`). `to_chrome_trace` lowers the
recorded spans to the Chrome trace-event JSON format (the `traceEvents`
array of "X" complete events), which both `chrome://tracing` and Perfetto
(ui.perfetto.dev) open directly, so an engine run can be inspected on a
real timeline: bucketed prefill launches, decode chunk cadence, lane
occupancy and emitted-token counts per chunk in the event args.

Spans carry host wall-clock (perf_counter) timestamps relative to the
engine's construction; timestamps are re-based to the earliest span so
traces start at t=0. Each span category ("prefill", "decode", ...) gets
its own track (tid) — the engine is single-threaded and step-locked, so
tracks encode phase, not concurrency.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed engine phase: a device call the host waited on."""

    name: str
    ts: float                  # start, seconds (engine-relative wall clock)
    dur: float                 # duration, seconds
    cat: str = "serve"         # track: "prefill" | "decode" | ...
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


def to_chrome_trace(spans: Iterable[Span], process_name: str = "sosa-serve",
                    pid: int = 1) -> dict:
    """Spans -> Chrome trace-event JSON document (Perfetto-loadable).

    Returns the standard object form: {"traceEvents": [...],
    "displayTimeUnit": "ms"}; every span becomes a complete ("X") event
    with microsecond ts/dur, plus process/thread metadata events naming
    the tracks.
    """
    spans = list(spans)
    cats = sorted({s.cat for s in spans})
    tids = {c: i + 1 for i, c in enumerate(cats)}
    t0 = min((s.ts for s in spans), default=0.0)
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    for cat, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": cat}})
    for s in spans:
        events.append({
            "name": s.name,
            "cat": s.cat,
            "ph": "X",
            "ts": (s.ts - t0) * 1e6,
            "dur": s.dur * 1e6,
            "pid": pid,
            "tid": tids[s.cat],
            "args": dict(s.args),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Span],
                       process_name: str = "sosa-serve") -> int:
    """Write spans as a Chrome trace-event JSON file; returns the number
    of span events written (excluding metadata events)."""
    spans = list(spans)
    doc = to_chrome_trace(spans, process_name=process_name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return len(spans)
