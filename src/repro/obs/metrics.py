"""Lightweight serving-telemetry metrics registry (zero deps, dict-backed).

The paper's headline metric is *effective* throughput/Watt — throughput
adjusted for array utilization (SOSA §6) — so the serving stack needs live
counters/gauges/histograms it can combine with the kernel layer's
padded-MAC utilization (parallel.autoshard) into an effective-TOPS gauge
(obs/drift.py). Three metric kinds, each a labeled series:

  * Counter   — monotonically increasing float (tokens served, cache hits,
                accumulated wall-clock seconds).
  * Gauge     — last-written value (queue depth, slot occupancy, tok/s).
  * Histogram — raw observations with percentile snapshots (per-token
                wait, decode chunk lengths).

A series is identified by ``(name, labels)``; ``registry.counter("x",
path="bucketed")`` get-or-creates it. ``snapshot()`` returns a plain dict
(JSON-serializable) keyed by the rendered series name ``x{path=bucketed}``
— greppable the same way benchmark ``derived`` fields are.

Design constraint (gated in tests/test_serving.py): recording must be
pure host-side Python — a metric write never touches a device array, so
metrics-on changes no jit cache entries and adds no host syncs.

``registry()`` returns the process-global default registry the kernel
layer records into; subsystems that want isolation (one ``ServeEngine``
per tenant) construct their own ``MetricsRegistry``.
"""

from __future__ import annotations

import dataclasses
import json
import math


def _render(name: str, labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


def percentile(values: list[float], q: float) -> float:
    """Linear-interpolation percentile (numpy-compatible), q in [0, 100]."""
    if not values:
        return float("nan")
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    pos = (len(xs) - 1) * q / 100.0
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return float(xs[lo])
    return float(xs[lo] + (xs[hi] - xs[lo]) * (pos - lo))


@dataclasses.dataclass
class Counter:
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        self.value += n


@dataclasses.dataclass
class Gauge:
    value: float = 0.0
    _written: bool = False

    def set(self, v: float) -> None:
        self.value = float(v)
        self._written = True


class Histogram:
    """Raw-observation histogram with a bounded buffer.

    Keeps up to ``max_samples`` observations (beyond that, every other
    retained sample is dropped and the stride doubles — a deterministic
    decimation that preserves the spread without unbounded memory); count
    and sum stay exact.
    """

    def __init__(self, max_samples: int = 8192):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: list[float] = []
        self._max_samples = max_samples
        self._stride = 1
        self._skip = 0

    def record(self, v: float, n: int = 1) -> None:
        """Record observation ``v`` (``n`` identical observations at once —
        e.g. a decode chunk charging every delivered token the chunk's
        wall time)."""
        v = float(v)
        self.count += n
        self.total += v * n
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        for _ in range(n):
            if self._skip > 0:
                self._skip -= 1
                continue
            self._samples.append(v)
            self._skip = self._stride - 1
            if len(self._samples) >= self._max_samples:
                self._samples = self._samples[::2]
                self._stride *= 2

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        return percentile(self._samples, q)

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Dict-backed labeled-series store; see module docstring."""

    def __init__(self):
        self._series: dict[tuple[str, str, tuple], object] = {}

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = (kind, name, tuple(sorted((k, str(v)) for k, v in
                                        labels.items())))
        metric = self._series.get(key)
        if metric is None:
            metric = self._series[key] = factory()
        return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels, Histogram)

    def value(self, name: str, **labels) -> float | None:
        """Current value of a counter/gauge series, or None if the series
        was never written (histograms: use ``find``)."""
        key_labels = tuple(sorted((k, str(v)) for k, v in labels.items()))
        for (kind, n, lbl), metric in self._series.items():
            if n == name and lbl == key_labels and kind in ("counter",
                                                            "gauge"):
                return metric.value
        return None

    def find(self, name: str) -> dict[str, object]:
        """All series of ``name`` (any labels), keyed by rendered name."""
        return {_render(n, lbl): m for (kind, n, lbl), m in
                self._series.items() if n == name}

    def clear(self) -> None:
        self._series.clear()

    def __len__(self) -> int:
        return len(self._series)

    def snapshot(self) -> dict:
        """JSON-serializable state: {counters: {...}, gauges: {...},
        histograms: {series: summary}}."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for (kind, name, labels), metric in sorted(self._series.items()):
            key = _render(name, labels)
            if kind == "counter":
                out["counters"][key] = metric.value
            elif kind == "gauge":
                out["gauges"][key] = metric.value
            else:
                out["histograms"][key] = metric.summary()
        return out

    def dumps(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)


_GLOBAL = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global default registry (kernel-layer autotune metrics
    land here; serving engines may pass their own)."""
    return _GLOBAL
