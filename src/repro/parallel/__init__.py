"""Distribution runtime: sharding rules, butterfly collectives (the paper's
interconnect as a collective schedule), SOSA-driven autosharding, gradient
compression."""

from .sharding import (act_pspec, batch_axes, batch_sharding, make_constrain,
                       pspec_for_axes, pspecs_from_schema,
                       shardings_from_schema, zero1_pspec)
from .collectives import (butterfly_all_gather, butterfly_all_reduce,
                          butterfly_all_reduce_expansion2,
                          butterfly_reduce_scatter, ring_all_reduce,
                          COLLECTIVES)
from .compression import compressed_psum, compression_ratio
from .autoshard import (ShardPlan, choose_blocks, choose_plan, device_gemms,
                        plan_report, tiles_exposed)
