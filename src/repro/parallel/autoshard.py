"""SOSA-model-driven sharding & blocking decisions.

The paper's three pillars, applied at mesh scale (DESIGN.md §2):

  1. *Granularity*: each TPU chip's MXU is a 128x128 weight-stationary
     array — a "pod". `choose_blocks` runs the same effective-throughput
     trade-off as core/dse.py over Pallas block candidates: larger blocks
     amortize HBM traffic (the paper's memory-energy term), smaller blocks
     avoid edge waste when layer dims don't divide (the utilization term).

  2. *Tiling*: `plan_report` counts the parallel tiles each sharding plan
     exposes per device-GEMM — the paper's "#tiles >= #pods" criterion
     decides how much batch/sequence partitioning a shape needs.

  3. *Interconnect*: plans are scored with the analytical wave model
     (core/simulator.analyze) on the per-device GEMM trace, so a plan that
     starves pods (too little partitioning) or thrashes memory (too much)
     loses — the Fig 12b curve, reproduced at mesh scale.
"""

from __future__ import annotations

import dataclasses
import math

from ..configs.base import ArchConfig, ShapeConfig
from ..core.arrays import ArrayConfig, AcceleratorConfig
from ..core.simulator import analyze
from ..core.tiling import GemmSpec
from ..core.workloads import transformer_lm

MXU = 128  # TPU MXU dimension: the per-chip "pod" granularity


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    name: str
    dp: int                 # batch ways (pod x data)
    tp: int                 # model ways
    microbatches: int = 1   # grad-accum splits (train only)
    seq_shard: bool = False # sequence-parallel residuals

    def describe(self) -> str:
        return (f"{self.name}: dp={self.dp} tp={self.tp} "
                f"ubatch={self.microbatches} sp={self.seq_shard}")


def device_gemms(cfg: ArchConfig, shape: ShapeConfig, plan: ShardPlan
                 ) -> list[GemmSpec]:
    """The GEMM trace one device executes under a plan (weight GEMMs of
    one layer stack pass, dims divided by the plan's ways)."""
    b_local = max(1, shape.global_batch // (plan.dp * plan.microbatches))
    seq = 1 if shape.is_decode else shape.seq_len
    heads = max(1, cfg.n_heads)
    tp_heads = plan.tp if heads % plan.tp == 0 else 1
    d_ff = cfg.moe.d_ff_expert if cfg.moe else max(1, cfg.d_ff)
    ff_local = max(1, d_ff // (1 if cfg.moe else plan.tp))
    return transformer_lm(
        n_layers=1,
        d_model=cfg.d_model,
        n_heads=max(1, heads // tp_heads),
        d_ff=ff_local,
        seq=seq,
        batch=b_local,
        vocab=0,
        n_kv_heads=max(1, cfg.n_kv_heads or 1),
        include_attention=not shape.is_decode,
    )


def tiles_exposed(gemms: list[GemmSpec], block: int = MXU) -> int:
    """Parallel tile count under the paper's r x r partitioning at MXU
    granularity — the quantity the tiling pillar maximizes."""
    total = 0
    for g in gemms:
        total += math.ceil(g.d1 / block) * math.ceil(g.d3 / block)
    return total


def candidate_plans(cfg: ArchConfig, shape: ShapeConfig, mesh_shape: dict
                    ) -> list[ShardPlan]:
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh_shape.get(ax, 1)
    tp = mesh_shape.get("model", 1)
    plans = [ShardPlan("dp-tp", dp, tp)]
    if shape.kind == "train":
        plans.append(ShardPlan("dp-tp+sp", dp, tp, seq_shard=True))
        for ub in (2, 4):
            if shape.global_batch // dp >= ub:
                plans.append(ShardPlan(f"dp-tp+ub{ub}", dp, tp,
                                       microbatches=ub, seq_shard=True))
    return plans


def score_plan(cfg: ArchConfig, shape: ShapeConfig, plan: ShardPlan,
               chip_pods: int = 1) -> float:
    """Effective throughput (TOPS @ chip power) of the per-device trace on
    an MXU-granularity pod model."""
    gemms = device_gemms(cfg, shape, plan)
    accel = AcceleratorConfig(
        array=ArrayConfig(rows=MXU, cols=MXU), num_pods=chip_pods,
        icn_mw_per_byte=0.0)
    res = analyze(gemms, accel, interconnect="crossbar")
    return res.effective_tops_at_tdp * plan.microbatches  # same total work


def choose_plan(cfg: ArchConfig, shape: ShapeConfig, mesh_shape: dict
                ) -> tuple[ShardPlan, list[tuple[str, float]]]:
    plans = candidate_plans(cfg, shape, mesh_shape)
    scored = [(p, score_plan(cfg, shape, p)) for p in plans]
    scored.sort(key=lambda t: -t[1])
    return scored[0][0], [(p.describe(), s) for p, s in scored]


def choose_blocks(m: int, k: int, n: int,
                  candidates=(128, 256, 512)) -> tuple[int, int, int]:
    """Pallas GEMM block sizes by the paper's effective-throughput metric:
    utilization (edge waste) x memory-energy proxy (bytes per MAC)."""
    best, best_score = (MXU, MXU, MXU), -1.0
    for bm in candidates:
        for bn in candidates:
            for bk in candidates:
                tiles_m, tiles_n, tiles_k = (math.ceil(m / bm),
                                             math.ceil(n / bn),
                                             math.ceil(k / bk))
                util = (m * n * k) / (tiles_m * bm * tiles_n * bn *
                                      tiles_k * bk)
                # bytes/MAC ~ 1/bm + 1/bn + 1/bk (edge traffic per block)
                mem = 1.0 / bm + 1.0 / bn + 1.0 / bk
                # VMEM: 3 buffers x (bm*bk + bk*bn + bm*bn) x 2B must fit
                vmem = 2 * 3 * (bm * bk + bk * bn + bm * bn)
                if vmem > 12 * 2 ** 20:
                    continue
                score = util / (1.0 + 64 * mem)
                if score > best_score:
                    best, best_score = (bm, bn, bk), score
    return best


def plan_report(cfg: ArchConfig, shape: ShapeConfig, mesh_shape: dict) -> str:
    plan, table = choose_plan(cfg, shape, mesh_shape)
    gemms = device_gemms(cfg, shape, plan)
    lines = [f"autoshard {cfg.name} x {shape.name}:"]
    for desc, score in table:
        lines.append(f"  {desc:40s} eff={score:8.2f} TOPS")
    lines.append(f"  -> {plan.describe()}; tiles/device="
                 f"{tiles_exposed(gemms)} (pods-per-chip criterion: >= 1)")
    return "\n".join(lines)
