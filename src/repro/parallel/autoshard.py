"""SOSA-model-driven sharding & blocking decisions.

The paper's three pillars, applied at mesh scale (DESIGN.md §2):

  1. *Granularity*: each TPU chip's MXU is a 128x128 weight-stationary
     array — a "pod". `choose_blocks` runs the same effective-throughput
     trade-off as core/dse.py over Pallas block candidates: larger blocks
     amortize HBM traffic (the paper's memory-energy term), smaller blocks
     avoid edge waste when layer dims don't divide (the utilization term).

  2. *Tiling*: `plan_report` counts the parallel tiles each sharding plan
     exposes per device-GEMM — the paper's "#tiles >= #pods" criterion
     decides how much batch/sequence partitioning a shape needs.

  3. *Interconnect*: plans are scored with the analytical wave model
     (core/simulator.analyze) on the per-device GEMM trace, so a plan that
     starves pods (too little partitioning) or thrashes memory (too much)
     loses — the Fig 12b curve, reproduced at mesh scale.
"""

from __future__ import annotations

import dataclasses
import functools
import math

from ..configs.base import ArchConfig, ShapeConfig
from ..core.arrays import ArrayConfig, AcceleratorConfig
from ..core.simulator import analyze
from ..core.tiling import GemmSpec, tile_stats
from ..core.workloads import transformer_lm

MXU = 128  # TPU MXU dimension: the per-chip "pod" granularity


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    name: str
    dp: int                 # batch ways (pod x data)
    tp: int                 # model ways
    microbatches: int = 1   # grad-accum splits (train only)
    seq_shard: bool = False # sequence-parallel residuals

    def describe(self) -> str:
        return (f"{self.name}: dp={self.dp} tp={self.tp} "
                f"ubatch={self.microbatches} sp={self.seq_shard}")


def device_gemms(cfg: ArchConfig, shape: ShapeConfig, plan: ShardPlan
                 ) -> list[GemmSpec]:
    """The GEMM trace one device executes under a plan (weight GEMMs of
    one layer stack pass, dims divided by the plan's ways)."""
    b_local = max(1, shape.global_batch // (plan.dp * plan.microbatches))
    seq = 1 if shape.is_decode else shape.seq_len
    heads = max(1, cfg.n_heads)
    tp_heads = plan.tp if heads % plan.tp == 0 else 1
    d_ff = cfg.moe.d_ff_expert if cfg.moe else max(1, cfg.d_ff)
    ff_local = max(1, d_ff // (1 if cfg.moe else plan.tp))
    return transformer_lm(
        n_layers=1,
        d_model=cfg.d_model,
        n_heads=max(1, heads // tp_heads),
        d_ff=ff_local,
        seq=seq,
        batch=b_local,
        vocab=0,
        n_kv_heads=max(1, cfg.n_kv_heads or 1),
        include_attention=not shape.is_decode,
    )


def tiles_exposed(gemms: list[GemmSpec], block: int = MXU) -> int:
    """Parallel tile count under the paper's r x r partitioning at MXU
    granularity — the quantity the tiling pillar maximizes."""
    total = 0
    for g in gemms:
        total += math.ceil(g.d1 / block) * math.ceil(g.d3 / block)
    return total


def candidate_plans(cfg: ArchConfig, shape: ShapeConfig, mesh_shape: dict
                    ) -> list[ShardPlan]:
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh_shape.get(ax, 1)
    tp = mesh_shape.get("model", 1)
    plans = [ShardPlan("dp-tp", dp, tp)]
    if shape.kind == "train":
        plans.append(ShardPlan("dp-tp+sp", dp, tp, seq_shard=True))
        for ub in (2, 4):
            if shape.global_batch // dp >= ub:
                plans.append(ShardPlan(f"dp-tp+ub{ub}", dp, tp,
                                       microbatches=ub, seq_shard=True))
    return plans


def score_plan(cfg: ArchConfig, shape: ShapeConfig, plan: ShardPlan,
               chip_pods: int = 1) -> float:
    """Effective throughput (TOPS @ chip power) of the per-device trace on
    an MXU-granularity pod model."""
    gemms = device_gemms(cfg, shape, plan)
    accel = AcceleratorConfig(
        array=ArrayConfig(rows=MXU, cols=MXU), num_pods=chip_pods,
        icn_mw_per_byte=0.0)
    res = analyze(gemms, accel, interconnect="crossbar")
    return res.effective_tops_at_tdp * plan.microbatches  # same total work


def choose_plan(cfg: ArchConfig, shape: ShapeConfig, mesh_shape: dict
                ) -> tuple[ShardPlan, list[tuple[str, float]]]:
    plans = candidate_plans(cfg, shape, mesh_shape)
    scored = [(p, score_plan(cfg, shape, p)) for p in plans]
    scored.sort(key=lambda t: -t[1])
    return scored[0][0], [(p.describe(), s) for p, s in scored]


# --------------------------------------------------------------------------
# tile_stats-driven Pallas block autotuner
# --------------------------------------------------------------------------
#
# The Pallas pod GEMM's (block_m, block_n, block_k) IS the paper's pod
# geometry: block_k is the array's contraction rows, block_n its output
# columns, block_m the activation rows streamed through per tile — so the
# same closed-form tiling model that drives the chip-level DSE
# (core.tiling.tile_stats with ArrayConfig(rows=block_k, cols=block_n),
# k_part=block_m) gives the kernel's exact grid counts (n_i, n_j, n_l).
# `choose_blocks` scores every candidate geometry with a roofline over
# those counts and is lru-cached per (shape, dtype) — the per-shape cache
# the serving hot loop relies on (one autotune per layer shape, ever).

# MXU peak: one 128x128 MAC wave per cycle; HBM: ~1 KiB/cycle at ~1 GHz
# (the v4-class ridge of ~16 MACs/byte — only the ratio matters here).
_MACS_PER_CYCLE = 128 * 128
_HBM_BYTES_PER_CYCLE = 1024
_VMEM_BUDGET = 12 * 2 ** 20   # working-set ceiling of the ~16 MiB VMEM


def _rup8(d: int) -> int:
    return max(8, ((d + 7) // 8) * 8)


@functools.lru_cache(maxsize=4096)
def _choose_blocks_cached(m: int, k: int, n: int,
                          candidates=(128, 256, 512),
                          dtype_bytes: int = 2, out_bytes: int = 4,
                          vmem_budget: int = _VMEM_BUDGET
                          ) -> tuple[int, int, int]:
    """The cached autotuner body behind `choose_blocks` (which adds the
    obs telemetry: cache hit/miss counters + per-shape utilization)."""
    # selection key: roofline time, then HBM traffic (a compute-bound tie
    # must not pick the max-traffic geometry), then VMEM footprint
    best, best_key = (MXU, MXU, MXU), (float("inf"),) * 3
    seen_eff: set[tuple[int, int, int]] = set()
    spec = [GemmSpec(d1=m, d2=k, d3=n)]
    for bm in candidates:
        for bn in candidates:
            for bk in candidates:
                # kernel-effective blocks (ops.systolic_gemm clips the same
                # way: min(block, sublane-rounded dim))
                bm_e = min(bm, _rup8(m))
                bn_e = min(bn, _rup8(n))
                bk_e = min(bk, _rup8(k))
                if (bm_e, bn_e, bk_e) in seen_eff:
                    continue
                seen_eff.add((bm_e, bn_e, bk_e))
                # VMEM working set: double-buffered streaming blocks + the
                # f32/int32 accumulator scratch + the output block
                vmem = (2 * (bm_e * bk_e + bk_e * bn_e) * dtype_bytes
                        + bm_e * bn_e * (4 + out_bytes))
                if vmem > vmem_budget:
                    continue
                st = tile_stats(spec, ArrayConfig(rows=bk_e, cols=bn_e),
                                k_part=bm_e)
                n_i, n_j, n_l = (int(st.n_i[0]), int(st.n_j[0]),
                                 int(st.n_l[0]))
                padded_macs = (n_i * bm_e) * (n_j * bk_e) * (n_l * bn_e)
                # HBM traffic of the kernel's K-minor grid walk: every
                # (i, j, l) step streams one x and one w block; outputs
                # write once per (i, l)
                traffic = (n_i * n_l * n_j * (bm_e * bk_e + bk_e * bn_e)
                           * dtype_bytes
                           + n_i * n_l * bm_e * bn_e * out_bytes)
                t = max(padded_macs / _MACS_PER_CYCLE,
                        traffic / _HBM_BYTES_PER_CYCLE)
                key = (t, traffic, vmem)
                if key < best_key:
                    best, best_key = (bm, bn, bk), key
    return best


def tile_utilization(m: int, k: int, n: int,
                     blocks: tuple[int, int, int]) -> float:
    """Padded-MAC utilization of an (m x k) @ (k x n) GEMM under a block
    geometry: useful MACs over the MACs the padded grid actually streams
    (the kernel pads every dim to its clipped block). This is the tile
    component of the paper's effective-throughput metric — the live
    effective-TOPS gauge (obs/drift.py) multiplies measured token
    throughput by it."""
    bm, bn, bk = blocks
    bm_e, bn_e, bk_e = (min(bm, _rup8(m)), min(bn, _rup8(n)),
                        min(bk, _rup8(k)))
    st = tile_stats([GemmSpec(d1=m, d2=k, d3=n)],
                    ArrayConfig(rows=bk_e, cols=bn_e), k_part=bm_e)
    n_i, n_j, n_l = int(st.n_i[0]), int(st.n_j[0]), int(st.n_l[0])
    padded = (n_i * bm_e) * (n_j * bk_e) * (n_l * bn_e)
    return (m * k * n) / padded if padded else 0.0


def choose_blocks(m: int, k: int, n: int,
                  candidates=(128, 256, 512),
                  dtype_bytes: int = 2, out_bytes: int = 4,
                  vmem_budget: int = _VMEM_BUDGET) -> tuple[int, int, int]:
    """Pallas GEMM block sizes for an (m x k) @ (k x n) GEMM, chosen by the
    SOSA DSE cost model (see kernels/systolic_gemm/systolic_gemm.py for the
    full autotuner contract).

    For each candidate (bm, bn, bk) the kernel-effective geometry (blocks
    clipped to the padded problem, exactly as ops.systolic_gemm clips) is
    scored as a roofline: max(padded-MAC compute time, HBM stream time)
    over `tile_stats`' closed-form grid counts, subject to the VMEM budget
    (double-buffered x/w blocks + accumulator + output block). Returns the
    best (block_m, block_n, block_k); results are lru-cached per shape
    (`choose_blocks.cache_info()` / `.cache_clear()` reach the cache).

    Every call records telemetry into the process-global obs registry
    (obs.metrics.registry): an `autotune.cache{result=hit|miss}` counter,
    and — on a miss — the chosen geometry (`autotune.choice{...}`) plus
    the shape's padded-MAC utilization gauge `autotune.tile_util{shape=
    MxKxN}`, the tile component of the live effective-TOPS gauge.
    Recording is host-side Python at trace time only (block choice happens
    while jit traces, never per device call).
    """
    before = _choose_blocks_cached.cache_info().misses
    blocks = _choose_blocks_cached(
        m, k, n, tuple(candidates), dtype_bytes, out_bytes, vmem_budget)
    hit = _choose_blocks_cached.cache_info().misses == before
    from ..obs.metrics import registry
    reg = registry()
    reg.counter("autotune.cache", result="hit" if hit else "miss").inc()
    if not hit:
        shape = f"{m}x{k}x{n}"
        bm, bn, bk = blocks
        reg.counter("autotune.choice", shape=shape,
                    blocks=f"{bm}x{bn}x{bk}").inc()
        reg.gauge("autotune.tile_util", shape=shape).set(
            tile_utilization(m, k, n, blocks))
    return blocks


choose_blocks.cache_info = _choose_blocks_cached.cache_info
choose_blocks.cache_clear = _choose_blocks_cached.cache_clear


@functools.lru_cache(maxsize=4096)
def choose_blocks_grouped(g: int, m: int, k: int, n: int,
                          candidates=(128, 256, 512),
                          dtype_bytes: int = 2, out_bytes: int = 4,
                          vmem_budget: int = _VMEM_BUDGET
                          ) -> tuple[int, int, int]:
    """Block geometry for the grouped pod GEMM: G independent (m x k x n)
    problems in one launch (kernels.systolic_gemm.grouped_systolic_gemm_
    pallas). The grid tiles the *per-group* problem and the VMEM working
    set is one group's blocks, so the score is exactly `choose_blocks` of
    (m, k, n): the group axis multiplies padded MACs and HBM traffic by G
    uniformly and cannot shift the roofline argmin. Kept as its own cached
    entry point so grouped shapes (MoE experts: small per-expert m = G_cap
    rows) autotune independently of the dense shapes they share dims with.
    """
    assert g >= 1
    return choose_blocks(m, k, n, candidates=candidates,
                         dtype_bytes=dtype_bytes, out_bytes=out_bytes,
                         vmem_budget=vmem_budget)


# The transposed-weight kernel (systolic_gemm_nt_pallas: x [M,K] @ w[N,K]^T,
# the tied-embedding LM head) reuses `choose_blocks(m, k, n)` unchanged:
# its w block is [bn, bk] instead of [bk, bn] — identical bytes, identical
# grid walk, identical psum-chain depth — so the roofline is layout-
# invariant. ops.systolic_gemm_t calls choose_blocks with the logical
# (M, K, N) of the product, exactly like the untransposed path.


def plan_report(cfg: ArchConfig, shape: ShapeConfig, mesh_shape: dict) -> str:
    plan, table = choose_plan(cfg, shape, mesh_shape)
    gemms = device_gemms(cfg, shape, plan)
    lines = [f"autoshard {cfg.name} x {shape.name}:"]
    for desc, score in table:
        lines.append(f"  {desc:40s} eff={score:8.2f} TOPS")
    lines.append(f"  -> {plan.describe()}; tiles/device="
                 f"{tiles_exposed(gemms)} (pods-per-chip criterion: >= 1)")
    return "\n".join(lines)
