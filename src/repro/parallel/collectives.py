"""Butterfly collectives — the paper's interconnect as a collective schedule.

SOSA's Butterfly network (§3.2, Fig 6) is a log2(N)-stage fabric where stage
t connects nodes differing in bit t. The distributed-training analogue is
the recursive-halving/doubling ("butterfly") all-reduce: log2(N) rounds of
pairwise exchange at doubling distances — the exact communication DAG of
Fig 6, expressed with shard_map + jax.lax.ppermute.

On a TPU torus XLA defaults to ring reductions (bandwidth-optimal for large
payloads: 2·(N-1)/N·bytes at N-1 latency hops). The butterfly schedule
moves the same total bytes in log2(N) rounds — latency-optimal for the
small/medium reductions SOSA targets (many small pods => many small
tensors). benchmarks/interconnect.py reports the crossover; the expansion-2
variant splits the payload over two disjoint plane schedules per round
(dual-ring analogue) like the paper's Butterfly-2.

All variants are exact (bit-reproducible vs jnp.sum ordering differences
bounded by fp associativity) and validated in tests/test_collectives.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _axis_size(axis_name: str) -> int:
    # jax.lax.axis_size only exists in newer jax; psum(1) works everywhere.
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return int(jax.lax.psum(1, axis_name))


def butterfly_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Recursive-doubling all-reduce: log2(N) ppermute rounds (Fig 6 DAG).

    Round t exchanges with the partner differing in bit t of the axis
    index; after all rounds every shard holds the full sum.
    """
    n = _axis_size(axis_name)
    assert n & (n - 1) == 0, "butterfly all-reduce needs power-of-two axis"
    rounds = int(math.log2(n))
    idx = jax.lax.axis_index(axis_name)
    for t in range(rounds):
        bit = 1 << t
        partner_perm = [(i, i ^ bit) for i in range(n)]
        other = jax.lax.ppermute(x, axis_name, partner_perm)
        x = x + other
    return x


def butterfly_all_reduce_expansion2(x: jax.Array, axis_name: str) -> jax.Array:
    """Butterfly-2: split the payload in half and run the two halves on
    plane-0 (LSB-first) and plane-1 (MSB-first) schedules — disjoint link
    sets per round, doubling effective injection bandwidth (the paper's
    expansion argument)."""
    n = _axis_size(axis_name)
    assert n & (n - 1) == 0
    rounds = int(math.log2(n))
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % 2
    if pad:
        flat = jnp.pad(flat, (0, pad))
    a, b = jnp.split(flat, 2)
    for t in range(rounds):
        bit_a = 1 << t                      # plane 0: LSB-first
        bit_b = 1 << (rounds - 1 - t)       # plane 1: MSB-first
        a = a + jax.lax.ppermute(a, axis_name,
                                 [(i, i ^ bit_a) for i in range(n)])
        b = b + jax.lax.ppermute(b, axis_name,
                                 [(i, i ^ bit_b) for i in range(n)])
    out = jnp.concatenate([a, b])
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


def butterfly_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Recursive-halving reduce-scatter: log2(N) rounds, halving payload
    each round; shard i ends with the i-th 1/N slice of the sum.
    x's leading dim must be divisible by N."""
    n = _axis_size(axis_name)
    assert n & (n - 1) == 0
    rounds = int(math.log2(n))
    idx = jax.lax.axis_index(axis_name)
    buf = x
    # walk bits MSB -> LSB: exchange the half we don't keep
    for t in range(rounds - 1, -1, -1):
        bit = 1 << t
        half = buf.shape[0] // 2
        lo, hi = buf[:half], buf[half:]
        has_bit = (idx & bit) != 0
        # keep the half matching our bit, ship the other to the partner
        keep = jax.lax.cond(has_bit, lambda: hi, lambda: lo)
        ship = jax.lax.cond(has_bit, lambda: lo, lambda: hi)
        other = jax.lax.ppermute(ship, axis_name,
                                 [(i, i ^ bit) for i in range(n)])
        buf = keep + other
    return buf


def butterfly_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Recursive-doubling all-gather (inverse of the reduce-scatter walk).

    Note: the gathered order is bit-reversal-composed; paired with
    `butterfly_reduce_scatter` (same bit walk) the composition
    all_gather(reduce_scatter(x)) == all_reduce(x) holds exactly, which is
    the only way we use it (ZeRO-1 gradient path)."""
    n = _axis_size(axis_name)
    assert n & (n - 1) == 0
    rounds = int(math.log2(n))
    idx = jax.lax.axis_index(axis_name)
    buf = x
    for t in range(rounds):
        bit = 1 << t
        other = jax.lax.ppermute(buf, axis_name,
                                 [(i, i ^ bit) for i in range(n)])
        has_bit = (idx & bit) != 0
        lo = jax.lax.cond(has_bit, lambda: other, lambda: buf)
        hi = jax.lax.cond(has_bit, lambda: buf, lambda: other)
        buf = jnp.concatenate([lo, hi], axis=0)
    return buf


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Baseline: 2(N-1)-step ring (reduce-scatter + all-gather), the
    torus-native schedule XLA favors — SOSA's mesh/H-tree analogue."""
    n = _axis_size(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    idx = jax.lax.axis_index(axis_name)
    ring = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: start with own chunk (idx+1); at step s, the incoming
    # partial is for chunk (idx - s) mod n — add our copy of it and pass on.
    acc = jnp.take(chunks, (idx + 1) % n, axis=0)
    for step in range(n - 1):
        acc = jax.lax.ppermute(acc, axis_name, ring)
        slot = (idx - step) % n
        acc = acc + jnp.take(chunks, slot, axis=0)
    # node idx now owns the fully reduced chunk (idx+2) mod n; all-gather
    out = [acc]
    cur = acc
    for step in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, ring)
        out.append(cur)
    # out[k] came from node (idx - k): it owns chunk (idx - k + 2) mod n
    stacked = jnp.stack(out)                    # [n, chunk]
    owners = (idx + 2 - jnp.arange(n)) % n
    ordered = jnp.zeros_like(stacked).at[owners].set(stacked)
    flat_out = ordered.reshape(-1)
    if pad:
        flat_out = flat_out[:-pad]
    return flat_out.reshape(x.shape)


COLLECTIVES = {
    "psum": lambda x, ax: jax.lax.psum(x, ax),
    "butterfly": butterfly_all_reduce,
    "butterfly2": butterfly_all_reduce_expansion2,
    "ring": ring_all_reduce,
}


def all_reduce_under_mesh(mesh: Mesh, axis_name: str, impl: str = "butterfly"):
    """shard_map-wrapped all-reduce over one mesh axis for replicated use:
    f(x sharded on axis) -> x summed, replicated on that axis."""
    fn = COLLECTIVES[impl]
    spec_in = P(axis_name)
    spec_out = P(axis_name)

    @functools.partial(shard_map, mesh=mesh, in_specs=spec_in,
                       out_specs=spec_out, check_rep=False)
    def _run(x):
        return fn(x, axis_name)

    return _run
