"""Gradient compression for the slow cross-pod (DCN) axis.

int8 block-quantized all-reduce with error feedback: the pod axis carries
only data-parallel gradient sums. A per-block scale is agreed across the
axis (pmax) so the int8 payloads accumulate *exactly* in int32; error
feedback carries each step's quantization residual into the next step,
keeping compressed SGD unbiased over time. 4x fewer bytes over the slowest
links — directly scales the collective roofline term of the multi-pod mesh
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _blocked(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def compressed_psum(x: jax.Array, axis_name: str,
                    error: jax.Array | None = None):
    """int8-compressed all-reduce with error feedback.

    Returns (reduced, new_error). Usable inside shard_map over `axis_name`.
    """
    if error is not None:
        x = x + error
    blocks, pad = _blocked(x)
    local_max = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    gmax = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)   # exact in int32
    red_blocks = qsum.astype(jnp.float32) * scale
    flat = red_blocks.reshape(-1)
    if pad:
        flat = flat[:-pad]
    reduced = flat.reshape(x.shape)

    deq_local = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        deq_local = deq_local[:-pad]
    new_error = x - deq_local.reshape(x.shape)
    return reduced, new_error


def compression_ratio(x_dtype=jnp.float32) -> float:
    """Bytes saved on the wire (scales are 1/BLOCK overhead)."""
    full = jnp.dtype(x_dtype).itemsize
    return full / (1.0 + 4.0 / BLOCK)
