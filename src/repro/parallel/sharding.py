"""Logical-axis -> PartitionSpec rules with divisibility guards.

Every ParamSpec carries logical axis names ("embed", "heads", "ff",
"experts", "vocab", ...). `pspecs_from_schema` maps them onto mesh axes via
RULES, dropping any assignment whose dimension is not divisible by the mesh
axis size (e.g. whisper's 12 heads or hymba's 25 heads on a 16-way model
axis fall back to replication — correctness first, the autosharder reports
the utilization cost).

Activation constraints use the same mechanism via `act_pspec`.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.layers import is_spec

# parameter logical axes -> preferred mesh axes (in priority order)
PARAM_RULES: dict[str, tuple[str, ...]] = {
    "embed": (),                # replicated (TP shards the other operand dim)
    "ff": ("model",),
    "expert_ff": (),            # experts already shard over model
    "heads": ("model",),
    "kv_heads": ("model",),     # guarded: kv counts rarely divide
    "experts": ("model",),
    "vocab": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "layers": (),
    None: (),
}

# activation tags -> pspec builders
ACT_RULES: dict[str, tuple] = {
    "residual": ("batch", None, None),          # [B, S, D]
    "logits": ("batch", None, "vocab_model"),   # [B, S, V]
}


def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 0


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod', 'data') when multi-pod, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def pspec_for_axes(axes: tuple, shape: tuple, mesh: Mesh,
                   rules: dict | None = None) -> P:
    rules = rules or PARAM_RULES
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        assigned: Optional[str] = None
        for cand in rules.get(ax, ()):
            if cand in mesh.shape and cand not in used:
                if dim % mesh.shape[cand] == 0 and dim >= mesh.shape[cand]:
                    assigned = cand
                    used.add(cand)
                    break
        out.append(assigned)
    return P(*out)


def pspecs_from_schema(schema, mesh: Mesh, rules: dict | None = None):
    return jax.tree.map(
        lambda s: pspec_for_axes(s.axes, s.shape, mesh, rules), schema,
        is_leaf=is_spec)


def fsdp_pspecs_from_schema(schema, mesh: Mesh, rules: dict | None = None):
    """TP rules + the DP axes sharded onto each param's largest free dim
    (FSDP/ZeRO-3): weights live fully sharded, GSPMD all-gathers one
    scanned layer at a time in the forward and reduce-scatters its grads —
    what makes the 236B/340B train cells and big-model serving fit HBM."""
    def spec(s):
        base = pspec_for_axes(s.axes, s.shape, mesh, rules)
        return zero1_pspec(base, s.shape, mesh)
    return jax.tree.map(spec, schema, is_leaf=is_spec)


# §Perf variant (llama-vision prefill hillclimb): attention goes
# sequence-parallel — q/k/v/o weights replicated (FSDP re-shards them over
# DP), so head-sharding's per-layer [B,S,D]-sized partial-sum reductions
# disappear; only the FFN keeps TP. The residual stays sequence-sharded
# and attention exchanges the (much smaller) KV tensors instead.
ATTN_SP_RULES = dict(PARAM_RULES)
ATTN_SP_RULES["heads"] = ()
ATTN_SP_RULES["kv_heads"] = ()


def shardings_from_schema(schema, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, pspec_for_axes(s.axes, s.shape, mesh)),
        schema, is_leaf=is_spec)


def act_pspec(kind: str, mesh: Mesh, shape: tuple | None = None,
              vocab: int | None = None,
              seq_shard: bool = False) -> P:
    """PartitionSpec for an activation tag. batch -> all DP axes;
    logits vocab dim -> model (if divisible); residual seq -> model when
    sequence parallelism is on."""
    dp = batch_axes(mesh)
    if kind == "residual":
        seq = ("model",) if (seq_shard and "model" in mesh.shape) else None
        return P(dp if dp else None, seq if seq else None, None)
    if kind == "logits":
        vshard = None
        if vocab is not None and "model" in mesh.shape and \
                vocab % mesh.shape["model"] == 0:
            vshard = "model"
        return P(dp if dp else None, None, vshard)
    if kind == "moe_dispatched" and shape is not None:
        # [G, E, C, D]: groups over DP, experts over model (EP) — pins the
        # dispatch->expert resharding to one all-to-all instead of letting
        # GSPMD replicate (§Perf)
        e_ok = ("model" in mesh.shape and len(shape) >= 2
                and shape[1] % mesh.shape["model"] == 0)
        g_ok = shape[0] % _dp_size(mesh) == 0
        return P(dp if (dp and g_ok) else None,
                 "model" if e_ok else None, None, None)
    return P()


def make_constrain(mesh: Mesh, vocab: int, seq_shard: bool = False):
    """The Model's `constrain` hook: with_sharding_constraint on tagged
    activations so GSPMD places collectives where we want them."""
    def constrain(x, kind: str):
        if mesh is None or x.ndim < 2:
            return x
        spec = act_pspec(kind, mesh, shape=x.shape, vocab=vocab,
                         seq_shard=seq_shard)
        if len(spec) > x.ndim:
            spec = P(*tuple(spec)[:x.ndim])
        if len(spec) < x.ndim:
            spec = P(*(tuple(spec) + (None,) * (x.ndim - len(spec))))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain


def batch_sharding(mesh: Mesh, ndim: int = 2) -> NamedSharding:
    """Input batch arrays: [B, S, ...] with B over all DP axes."""
    dp = batch_axes(mesh)
    return NamedSharding(mesh, P(dp if dp else None,
                                 *([None] * (ndim - 1))))


def cache_pspecs(cache_tree, mesh: Mesh, mla_seq_shard: bool = False,
                 kv_seq_shard: bool = False):
    """PartitionSpecs for a serving cache pytree (built by Model.init_cache).

    Layouts are fixed by construction (models/attention, models/ssm,
    models/transformer): the dataclass field name at the end of the tree
    path identifies each leaf, and the rank disambiguates stacked vs
    unstacked. Batch dims shard over the DP axes; KV-head / SSM-head dims
    over `model` when divisible (virtual-KV replication in Model.kv_rep
    makes the decode caches divisible for GQA archs).
    """
    dp = batch_axes(mesh)
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)
    msize = mesh.shape.get("model", 1)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        field = str(getattr(path[-1], "name", getattr(path[-1], "key", "")))
        nd = len(leaf.shape)
        spec = [None] * nd
        if field in ("k", "v"):            # [(L[,G]),B,S,KV,hd]
            b_ax, s_ax, kv_ax = nd - 4, nd - 3, nd - 2
            if leaf.shape[b_ax] % _dp_size(mesh) == 0:
                spec[b_ax] = dpa
            if msize > 1 and leaf.shape[kv_ax] % msize == 0:
                spec[kv_ax] = "model"
            elif kv_seq_shard and msize > 1 and \
                    leaf.shape[s_ax] % msize == 0 and leaf.shape[s_ax] > 1:
                # §Perf: heads don't divide the model axis (whisper kv=12
                # on 16) — shard the cache SEQUENCE instead (flash-decode
                # over sequence shards; GSPMD distributes the softmax)
                spec[s_ax] = "model"
        elif field in ("c_kv", "k_rope"):  # [(L,)B,S,R] — latent cache
            b_ax, s_ax = nd - 3, nd - 2
            if leaf.shape[b_ax] % _dp_size(mesh) == 0:
                spec[b_ax] = dpa
            # §Perf: flash-decode style — shard the latent cache's SEQUENCE
            # over the model axis (the R dim is contracted in the absorbed
            # decode, so GSPMD turns softmax/out into psums over `model`)
            if mla_seq_shard and msize > 1 and \
                    leaf.shape[s_ax] % msize == 0:
                spec[s_ax] = "model"
        elif field == "conv":              # [(L,)B,K-1,C]
            b_ax, c_ax = nd - 3, nd - 1
            if leaf.shape[b_ax] % _dp_size(mesh) == 0:
                spec[b_ax] = dpa
            if msize > 1 and leaf.shape[c_ax] % msize == 0:
                spec[c_ax] = "model"
        elif field == "state":             # [(L,)B,H,P,N]
            b_ax, h_ax = nd - 4, nd - 3
            if leaf.shape[b_ax] % _dp_size(mesh) == 0:
                spec[b_ax] = dpa
            if msize > 1 and leaf.shape[h_ax] % msize == 0:
                spec[h_ax] = "model"
        # length vectors and anything unknown stay replicated
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return max(1, n)


def zero1_pspec(param_pspec: P, shape: tuple, mesh: Mesh) -> P:
    """ZeRO-1: optimizer-state sharding — add DP axes onto the largest
    unsharded dim of the param spec (guarded by divisibility)."""
    dp = batch_axes(mesh)
    if not dp:
        return param_pspec
    # idempotent: FSDP param specs already carry the DP axes
    used = set()
    for entry in param_pspec:
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            used.add(a)
    if any(a in used for a in dp):
        return param_pspec
    dp_size = math.prod(_mesh_axis_size(mesh, a) for a in dp)
    spec = list(param_pspec) + [None] * (len(shape) - len(param_pspec))
    # pick the largest dim currently unsharded and divisible by dp
    best, best_dim = -1, 0
    for i, (d, s) in enumerate(zip(shape, spec)):
        if s is None and d % dp_size == 0 and d > best_dim:
            best, best_dim = i, d
    if best >= 0:
        spec[best] = dp if len(dp) > 1 else dp[0]
    return P(*spec)
