"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (per-device on a
partitioned module; x chips to totalize). collective_bytes is parsed from
compiled.as_text(): a first pass builds the instruction -> shape symbol
table, a second sums *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
HBM_PER_CHIP = 16 * 2 ** 30  # v5e capacity

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# "%name = f32[8,128]{1,0} op-name(%a, %b), ..."  (also tuple shapes)
_DEF_RE = re.compile(r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}: ]+?)\s+"
                     r"([\w\-]+)\(([^)]*)\)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape like 'bf16[8,128]{1,0}' or a '(tuple, ...)'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device bytes moved per collective kind (operand sizes)."""
    shapes: dict[str, str] = {}
    ops: list[tuple[str, str, str]] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, shape, opname, operands = m.groups()
        shapes[name] = shape
        base = opname.rstrip(".0123456789")
        if any(base.startswith(c) for c in COLLECTIVE_OPS):
            ops.append((base, operands, shape))

    out = {c: 0 for c in COLLECTIVE_OPS}
    for base, operands, result_shape in ops:
        kind = next(c for c in COLLECTIVE_OPS if base.startswith(c))
        nbytes = 0
        for opnd in operands.split(","):
            opnd = opnd.strip().lstrip("%")
            # operands may carry inline shapes: "bf16[4,8]{1,0} %x"
            if " " in opnd:
                nbytes += _shape_bytes(opnd.split(" ")[0])
            elif opnd in shapes:
                nbytes += _shape_bytes(shapes[opnd])
        if nbytes == 0:  # fall back to result size
            nbytes = _shape_bytes(result_shape)
        out[kind] += nbytes
    out["total"] = sum(out[c] for c in COLLECTIVE_OPS)
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW
    collective_by_kind: dict | None = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def model_flops_ratio(self, model_flops_total: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        hlo_total = self.flops_per_device * self.chips
        return model_flops_total / hlo_total if hlo_total else 0.0

    def roofline_fraction(self, model_flops_total: float) -> float:
        """useful-FLOPs time at peak / bound time — the §Perf score."""
        useful_s = model_flops_total / (self.chips * self.peak_flops)
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self, model_flops_total: float | None = None) -> dict:
        d = {
            "name": self.name,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
        }
        if model_flops_total is not None:
            d["model_flops"] = model_flops_total
            d["model_flops_ratio"] = self.model_flops_ratio(model_flops_total)
            d["roofline_fraction"] = self.roofline_fraction(model_flops_total)
        return d


def from_compiled(name: str, compiled, chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(compiled.as_text())
    r = Roofline(
        name=name, chips=chips, flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=float(coll["total"]))
    r.collective_by_kind = {k: v for k, v in coll.items() if k != "total"}
    return r


def model_flops(n_params_active: float, tokens: float,
                train: bool) -> float:
    """6·N·D for a train step (fwd+bwd), 2·N·D for inference."""
    return (6.0 if train else 2.0) * n_params_active * tokens
