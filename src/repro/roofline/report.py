"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
reports/dryrun/**.json.

    PYTHONPATH=src python -m repro.roofline.report [--mesh pod_16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(mesh: str, include_tagged: bool = False) -> list[dict]:
    rows = []
    for path in glob.glob(os.path.join(REPORT_DIR, mesh, "*.json")):
        with open(path) as f:
            r = json.load(f)
        stem = os.path.splitext(os.path.basename(path))[0]
        if not include_tagged and stem != f"{r['arch']}__{r['shape']}":
            continue  # hillclimb/diagnostic variants live in §Perf
        rows.append(r)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return rows


def fmt_dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | status | HBM GB/chip | fit16GB | compile s |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                       f"{r.get('error', '?')[:60]} | — | — | "
                       f"{r.get('compile_s', 0)} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{r.get('hbm_gb_per_chip', float('nan')):.2f} | "
            f"{'Y' if r.get('hbm_fit') else 'N'} | {r['compile_s']:.0f} |")
    return "\n".join(out)


def fmt_roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['bottleneck']} | {r.get('model_flops_ratio', 0):.2f} | "
            f"{r.get('roofline_fraction', 0):.3f} |")
    return "\n".join(out)


def pick_hillclimb(rows: list[dict]) -> list[tuple[str, str, str]]:
    ok = [r for r in rows if r["status"] == "ok"]
    worst = min(ok, key=lambda r: r.get("roofline_fraction", 1.0))
    coll = max(ok, key=lambda r: (r["collective_s"] /
                                  max(1e-12, max(r["compute_s"],
                                                 r["memory_s"]))))
    return [(worst["arch"], worst["shape"], "worst roofline fraction"),
            (coll["arch"], coll["shape"], "most collective-bound")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    args = ap.parse_args()
    rows = load(args.mesh)
    print(f"## Dry-run ({args.mesh}, {len(rows)} cells)\n")
    print(fmt_dryrun_table(rows))
    print(f"\n## Roofline ({args.mesh})\n")
    print(fmt_roofline_table(rows))
    print("\nhillclimb candidates:", pick_hillclimb(rows))


if __name__ == "__main__":
    main()
