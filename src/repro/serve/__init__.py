from .engine import Request, ServeEngine
from .reference import ReferenceEngine
