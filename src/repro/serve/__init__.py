from .admission import (AdmissionConfig, AdmissionController, EDF, FIFO,
                        InvalidRequest, POLICIES, SLO_AWARE, ServeStalled,
                        TERMINAL_STATES, WaveLatencyPredictor)
from .chaos import (ChaosConfig, FaultInjector, PermanentFault,
                    SlowChunkDetector, TransientDeviceError, VirtualClock)
from .engine import Request, ServeEngine
from .paging import PageLeak, PagePool
from .reference import ReferenceEngine
