"""SLO-aware admission control and overload protection for serving.

The engines (serve/engine.py, serve/reference.py) admit FIFO whenever a
slot frees; at offered load beyond array capacity that policy melts down:
queues grow without bound, every request misses its deadline, and the
effective throughput the paper headlines (§6: throughput x utilization)
collapses even though the GEMMs stay busy. This module makes admission a
policy object threaded through both engines:

  * **Terminal states** — every submitted request ends in exactly ONE of
    ``done`` / ``rejected`` / ``expired`` (`Request.state`); malformed
    requests never enter the queue at all (`InvalidRequest` at submit,
    naming the offending field), and an engine that runs out of steps with
    work still pending raises `ServeStalled` naming the stuck requests
    instead of returning silently.

  * **Policies** — `fifo` (the seed behavior, bit-identical when no
    deadlines/bounds are set), `edf` (earliest-deadline-first ordering +
    deadline expiry), and `slo-aware` (EDF ordering plus *predictive*
    shedding and overload degradation). The slo-aware policy prices each
    request with the tenancy wave model: `tenancy.trace.request_gemms`
    lowers (prompt_len, new_tokens) to the GEMM stream the engine would
    run, `tenancy.planner.predict_latency_s` turns it into model-space
    service seconds, and an online EWMA calibration (measured wall seconds
    per model second, `train.fault.Ewma`) maps the prediction to this
    box's wall clock. A request whose calibrated prediction cannot meet
    its deadline is shed *before* it burns prefill cycles — the same
    met/missed accounting `TenancyPlan.slo_attainment` reports, now
    choosing.

  * **Backpressure** — `max_queue` bounds the queue; a full queue sheds
    per policy (fifo/edf reject the arrival; slo-aware prefers shedding a
    queued request already predicted to miss). Under sustained overload
    (queue deeper than `overload_queue_per_slot x slots`) the slo-aware
    policy shrinks admitted decode budgets (graceful degradation: shorter
    completions for everyone beats no completions for the tail).

Deadline checks run at the engines' existing sync points (the per-chunk
host sync in ServeEngine — zero new syncs, the PR 7 discipline; per-token
in the reference oracle). All controller state is host-side Python.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Optional

from ..tenancy.planner import predict_latency_s
from ..tenancy.trace import request_gemms
from ..train.fault import Ewma

# terminal + lifecycle states (Request.state)
NEW = "new"
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
REJECTED = "rejected"
EXPIRED = "expired"
TERMINAL_STATES = (DONE, REJECTED, EXPIRED)

# policies
FIFO = "fifo"
EDF = "edf"
SLO_AWARE = "slo-aware"
POLICIES = (FIFO, EDF, SLO_AWARE)

# rows, cols, interconnect, pods — the paper-scale default design point
# the wave-model prediction prices requests on (obs.drift.DEFAULT_DESIGN)
DEFAULT_DESIGN = (32, 32, "butterfly-2", 64)


class InvalidRequest(ValueError):
    """A request that must never reach the hot loop; `.field` names the
    offending Request attribute."""

    def __init__(self, field: str, message: str):
        super().__init__(f"invalid request: {message} (field: {field})")
        self.field = field


class ServeStalled(RuntimeError):
    """run_to_completion exhausted max_steps with work still pending —
    the engine is wedged (or max_steps was too small). Carries the stuck
    request ids and their states."""

    def __init__(self, pending: dict[int, str], max_steps: int):
        self.pending = dict(pending)
        self.max_steps = max_steps
        detail = ", ".join(f"rid {r}: {s}" for r, s in
                           sorted(self.pending.items()))
        super().__init__(
            f"serving stalled: {len(pending)} request(s) still pending "
            f"after max_steps={max_steps} ({detail})")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Policy knobs; the defaults reproduce the seed engine exactly."""

    policy: str = FIFO
    max_queue: Optional[int] = None        # bounded queue; None = unbounded
    design: tuple = DEFAULT_DESIGN         # wave-model pricing point
    tdp: float = 400.0
    overload_queue_per_slot: float = 2.0   # queue > f*slots => overloaded
    degrade_budget_frac: float = 0.5       # slo-aware budget shrink factor
    calibration_alpha: float = 0.4         # EWMA for wall/model seconds
    faulty_pods: int = 0                   # pods masked out of the design
    #                                        point: predictions price on
    #                                        the degraded array, so the
    #                                        slo-aware policy sheds load
    #                                        proportionally to lost
    #                                        capacity

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown admission policy {self.policy!r}; "
                f"choose from {POLICIES}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if not 0 <= self.faulty_pods < self.design[3]:
            raise ValueError(
                f"faulty_pods must be in [0, {self.design[3]}) for design "
                f"{self.design}, got {self.faulty_pods}")


class WaveLatencyPredictor:
    """Per-request service-time prediction from the tenancy wave model.

    `model_seconds(prompt_len, new_tokens)` is the analytical latency of
    the request's own GEMM stream (tenancy.trace.request_gemms lowered at
    decode lanes=1 — the conservative solo estimate) on the configured
    design point. Results are memoized on (pow2 prompt bucket, exact
    token budget) in a bounded LRU: prompt bucketing alone bounds one key
    axis, but a long-lived server seeing varied budgets would grow the
    other without limit (the unbounded-cache bugfix). `cache_cap` entries
    (~4096 * a few dozen bytes) is the hard ceiling; eviction is
    least-recently-used, so steady traffic mixes never thrash.
    """

    def __init__(self, cfg, design: tuple = DEFAULT_DESIGN,
                 tdp: float = 400.0, faulty_pods: int = 0,
                 cache_cap: int = 4096):
        self.cfg = cfg
        self.design = design
        self.tdp = tdp
        self.faulty_pods = int(faulty_pods)
        self.cache_cap = max(1, int(cache_cap))
        self._cache: OrderedDict[tuple[int, int], float] = OrderedDict()

    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << max(0, int(n) - 1).bit_length()

    def model_seconds(self, prompt_len: int, new_tokens: int) -> float:
        key = (self._bucket(prompt_len), int(new_tokens))
        hit = self._cache.get(key)
        if hit is None:
            gemms = request_gemms(self.cfg, key[0], key[1])
            hit = self._cache[key] = predict_latency_s(
                gemms, self.design, self.tdp,
                faulty_pods=self.faulty_pods)
            if len(self._cache) > self.cache_cap:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return hit


class AdmissionController:
    """Host-side admission/overload policy shared by both engines.

    The engine owns the queue list and the slots; the controller owns the
    *decisions*: validation, enqueue/shed on submit, queue ordering,
    deadline expiry, predictive shedding, and budget degradation. It also
    keeps the live SLO ledger (`slo_attainment`) and the wall-clock
    calibration EWMA the slo-aware policy predicts with.
    """

    def __init__(self, config: AdmissionConfig, slots: int, max_len: int,
                 predictor: Optional[WaveLatencyPredictor] = None,
                 metrics=None, clock: Callable[[], float] = None):
        self.config = config
        self.slots = slots
        self.max_len = max_len
        self.predictor = predictor
        self.metrics = metrics
        self._calibration = Ewma(alpha=config.calibration_alpha)
        # live ledger (always on — host ints, no metrics required)
        self.counts = {"submitted": 0, "admitted": 0, "done": 0,
                       "rejected": 0, "expired": 0, "degraded": 0}
        self._slo_met = 0
        self._slo_declared = 0
        self._seq = 0                       # submit order for stable sorts
        self.pool = None                    # serve/paging.PagePool, opt-in

    # -- paged-KV hooks --------------------------------------------------
    def attach_pool(self, pool) -> None:
        """Paged serving: free PAGES, not free slots, become the gating
        resource. The controller then (a) rejects at submit any request
        whose worst-case page count exceeds the whole pool (it could never
        run — terminal reason ``pages-exhausted``), and (b) under the
        slo-aware policy sheds queued requests whose predicted wait for a
        reservation pushes them past their deadline (``shed-page-
        exhaustion``). The engine still does the actual reserve/release at
        its chunk sync."""
        self.pool = pool

    def _worst_pages(self, req) -> int:
        budget = min(req.max_new_tokens - 1,
                     max(0, self.max_len - len(req.prompt)))
        return self.pool.worst_pages(len(req.prompt), budget)

    def _predicted_page_miss(self, req, now: float) -> bool:
        if self.pool is None or req._deadline is None:
            return False
        short = self.pool.reserved_pages + self._worst_pages(req) \
            - self.pool.n_pages
        if short <= 0:
            return False                    # reservable right now
        wait = self.pool.estimated_wait_s(short)
        if wait is None:
            return False                    # no free-rate sample yet
        service = self.predicted_wall_seconds(
            len(req.prompt), req.max_new_tokens) or 0.0
        return now + wait + service > req._deadline

    # -- validation (satellite: typed errors at submit) -----------------
    def validate(self, req) -> None:
        if len(req.prompt) == 0:
            raise InvalidRequest("prompt", "empty prompt")
        if len(req.prompt) > self.max_len:
            raise InvalidRequest(
                "prompt", f"prompt length {len(req.prompt)} exceeds "
                          f"max_len {self.max_len}")
        if req.max_new_tokens <= 0:
            raise InvalidRequest(
                "max_new_tokens",
                f"token budget must be > 0, got {req.max_new_tokens}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            raise InvalidRequest(
                "deadline_s", f"deadline must be > 0 seconds from submit, "
                              f"got {req.deadline_s}")

    # -- terminal transitions -------------------------------------------
    def _finalize(self, req, state: str, reason: str,
                  met: bool = False) -> None:
        req.state = state
        req.reason = reason
        self.counts[state] += 1
        if req.deadline_s is not None:
            self._slo_declared += 1
            self._slo_met += int(met)
        if self.metrics is not None and state in (REJECTED, EXPIRED):
            self.metrics.counter(f"serve.admission.{state}",
                                 reason=reason).inc()

    def reject(self, req, reason: str) -> None:
        self._finalize(req, REJECTED, reason)

    def expire(self, req, reason: str) -> None:
        self._finalize(req, EXPIRED, reason)

    def finish(self, req, now: Optional[float] = None) -> None:
        """Completion. A request that finished after its deadline is still
        `done` (the tokens exist) but counts as an SLO miss."""
        req.done = True
        met = req._deadline is None or (now is not None
                                        and now <= req._deadline)
        self._finalize(req, DONE, "", met=met)

    @property
    def slo_attainment(self) -> float:
        """Fraction of finished deadline-carrying requests that completed
        (TenancyPlan.slo_attainment semantics, measured live: a request
        that was shed or expired missed its SLO by definition)."""
        if not self._slo_declared:
            return 1.0
        return self._slo_met / self._slo_declared

    # -- calibration (model seconds -> this box's wall clock) -----------
    def observe_service(self, model_seconds: float,
                        wall_seconds: float) -> None:
        if model_seconds > 0 and wall_seconds > 0:
            self._calibration.observe(wall_seconds / model_seconds)

    def predicted_wall_seconds(self, prompt_len: int,
                               new_tokens: int) -> Optional[float]:
        """Calibrated wall-clock service prediction; None until both a
        predictor and at least one calibration sample exist (the policy
        admits optimistically while unwarmed)."""
        if self.predictor is None or self._calibration.value is None:
            return None
        return self._calibration.value * self.predictor.model_seconds(
            prompt_len, new_tokens)

    # -- submit-time decision -------------------------------------------
    def on_submit(self, queue: list, req, now: float) -> bool:
        """Validate, stamp, and enqueue-or-shed. Returns True when the
        request should be appended to the queue (the engine owns the
        append); on False the request has already been finalized."""
        self.validate(req)
        self.counts["submitted"] += 1
        self._seq += 1
        req._seq = self._seq
        req._submit_t = now
        req._deadline = None if req.deadline_s is None \
            else now + req.deadline_s
        req.state = QUEUED
        if self.pool is not None and self._worst_pages(req) > \
                self.pool.n_pages:
            # larger than the entire page pool: no amount of waiting lets
            # this request reserve, so fail it loudly at the door
            self.reject(req, "pages-exhausted")
            return False
        if self.config.max_queue is None or \
                len(queue) < self.config.max_queue:
            return True
        # queue full: shed. slo-aware prefers evicting a queued request
        # already predicted to miss its deadline (it would be shed at the
        # next sweep anyway); fifo/edf apply plain arrival backpressure.
        if self.config.policy == SLO_AWARE:
            victim = next((q for q in queue
                           if self._predicted_miss(q, now)), None)
            if victim is not None:
                queue.remove(victim)
                self.reject(victim, "shed-predicted-miss")
                return True
        self.reject(req, "queue-full")
        return False

    def _predicted_miss(self, req, now: float) -> bool:
        if req._deadline is None:
            return False
        pred = self.predicted_wall_seconds(
            len(req.prompt), req.max_new_tokens)
        return pred is not None and now + pred > req._deadline

    # -- per-quantum queue sweep ----------------------------------------
    def sweep(self, queue: list, now: float) -> None:
        """Expire/shed and reorder the queue in place — called once per
        scheduling quantum before admission (pure host work)."""
        keep = []
        for req in queue:
            if req._deadline is not None and now >= req._deadline:
                self.expire(req, "queued-past-deadline")
            elif self.config.policy == SLO_AWARE and \
                    self._predicted_miss(req, now):
                self.reject(req, "shed-predicted-miss")
            elif self.config.policy == SLO_AWARE and \
                    self._predicted_page_miss(req, now):
                self.reject(req, "shed-page-exhaustion")
            else:
                keep.append(req)
        queue[:] = keep
        if self.config.policy == FIFO:
            return
        # edf/slo-aware: earliest deadline first, then priority (lower =
        # more urgent), then arrival order; no-deadline requests last
        queue.sort(key=lambda r: (
            r._deadline if r._deadline is not None else float("inf"),
            r.priority, r._seq))

    # -- admission-time hooks -------------------------------------------
    def overloaded(self, queue_len: int) -> bool:
        return queue_len > self.config.overload_queue_per_slot * self.slots

    def clamp_budget(self, req, base_budget: int, queue_len: int) -> int:
        """Graceful degradation: under overload the slo-aware policy
        shrinks the decode budget of newly admitted requests (the
        `_clamped_budget` shrink of the issue) so slots recycle faster."""
        if self.config.policy != SLO_AWARE or base_budget <= 1 or \
                not self.overloaded(queue_len):
            return base_budget
        shrunk = max(1, int(base_budget * self.config.degrade_budget_frac))
        if shrunk < base_budget:
            self.counts["degraded"] += 1
            if self.metrics is not None:
                self.metrics.counter("serve.admission.degraded").inc()
        return shrunk

    def note_admitted(self, req, now: float) -> None:
        req.state = RUNNING
        req._admit_t = now
        self.counts["admitted"] += 1
        if self.metrics is not None:
            self.metrics.histogram("serve.queue_wait_us").record(
                (now - req._submit_t) * 1e6)

    # -- chunk-boundary deadline enforcement ----------------------------
    def expired_lanes(self, active: list, now: float) -> list[int]:
        """Slots whose running request's deadline has passed — checked at
        the engines' existing sync points, never mid-chunk."""
        return [i for i, r in enumerate(active)
                if r is not None and r._deadline is not None
                and now >= r._deadline]
