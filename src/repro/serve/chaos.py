"""Deterministic fault injection for the serving hot loop.

Robustness claims ("sheds load, honors deadlines, never wedges or leaks
slots") are only testable if failure is reproducible. This module injects
a *seeded* failure schedule into the engines' device-call boundary:

  * **Transient device errors** — a call site drawn faulty raises
    `TransientDeviceError` for `transient_tries` consecutive attempts,
    then succeeds; the engine retries with exponential backoff
    (`ServeEngine(max_retries=...)`). Retries exhausted escalates to
    `PermanentFault` and the engine finalizes the affected requests as
    ``rejected`` (reason ``device-fault``) without leaking their slots.
  * **Slow chunks / prefills** — a call drawn slow stalls for
    `slow_factor x` the nominal service time before running. Paired with
    the EWMA slow-chunk detector below (train/fault.py's `Ewma`, the
    StragglerPolicy discipline at chunk granularity), the engine halves
    its next decode chunk when flagged, so deadline checks tighten
    exactly when the device degrades.
  * **Virtual time** — all injection acts on the engine's injectable
    clock. With `VirtualClock`, time only advances when the harness says
    so (`service_seconds` per device call, `slow_factor` on slow draws,
    backoff on retries), making deadline expiry, EWMA detection, and
    backoff schedules exactly reproducible on any box.

The schedule is a pure function of ``(seed, kind, call_index)`` — two runs
with the same config see byte-identical fault sequences regardless of
timing, retries, or host load.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Optional

from ..train.fault import Ewma


class TransientDeviceError(RuntimeError):
    """An injected, retryable device failure (the XLA 'transient
    RESOURCE_EXHAUSTED / preempted' class of errors)."""


class PermanentFault(RuntimeError):
    """Retries exhausted on one device call; the engine shelves the
    affected requests and keeps serving everyone else."""


class SilentCorruption(RuntimeError):
    """The PodGuard detected corruption it could not repair in-graph
    (multi-element hit under abft, or any detection under the
    detect-only probe mode). Retryable like TransientDeviceError —
    recompute usually clears a transient flip — but retries exhausted
    finalize the affected requests as ``rejected`` with terminal reason
    ``sdc-uncorrectable`` instead of escalating to PermanentFault."""


class NumericalFault(RuntimeError):
    """Non-finite logits (NaN/Inf) surfaced in one or more lanes. Not
    retryable: the forward pass is deterministic, so recompute returns
    the same poison — the engine rejects exactly the affected lanes
    (terminal reason ``non-finite-logits``) and keeps serving the rest.
    ``lanes`` lists the offending slot indices."""

    def __init__(self, lanes, where: str = "decode"):
        self.lanes = list(lanes)
        self.where = where
        super().__init__(
            f"non-finite logits in {where} lane(s) {self.lanes}")


def check_lanes_finite(bad_lanes, where: str = "decode") -> None:
    """Raise NumericalFault listing every flagged lane; no-op when all
    lanes are finite. ``bad_lanes`` is an iterable of (lane, flagged)
    pairs or a mapping lane -> flagged."""
    if hasattr(bad_lanes, "items"):
        bad_lanes = bad_lanes.items()
    flagged = [lane for lane, bad in bad_lanes if bad]
    if flagged:
        raise NumericalFault(flagged, where)


class VirtualClock:
    """Deterministic manual clock: callable like time.perf_counter, and
    sleeps advance it instead of blocking."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(dt)


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded failure schedule knobs. Probabilities are per device call,
    drawn independently per (seed, kind, call_index)."""

    seed: int = 0
    p_fault: float = 0.0           # transient-error probability per call
    p_slow: float = 0.0            # slow-call probability per call
    slow_factor: float = 4.0       # stall = (slow_factor-1) x service time
    transient_tries: int = 1       # consecutive failures per faulty site
    service_seconds: float = 0.0   # nominal virtual seconds per call
                                   # (advanced on the engine clock; 0 = off)
    p_sdc: float = 0.0             # silent-corruption probability per call
                                   # (requires a guard-enabled engine)
    sdc_elems: int = 1             # corrupted elements per hit (2+ defeats
                                   # single-corruption ABFT -> uncorrectable)
    sdc_magnitude: float = 1e4     # additive corruption magnitude
    sdc_target: int = 0            # which guarded GEMM (trace index) is hit


@dataclasses.dataclass
class SlowChunkDetector:
    """StragglerPolicy's EWMA discipline on the decode-chunk stream: a
    chunk slower per token than `slow_factor x` the EWMA baseline earns a
    strike; `patience` consecutive strikes flags the device as degraded
    (the engine reacts by halving the next chunk). One Ewma, one stream —
    the serving-side sibling of train.fault.StragglerPolicy."""

    slow_factor: float = 2.0
    patience: int = 2
    ewma: Ewma = dataclasses.field(default_factory=lambda: Ewma(alpha=0.3))
    strikes: int = 0
    flagged_chunks: int = 0

    def observe(self, seconds_per_token: float) -> bool:
        """Fold one chunk's per-token seconds in; True when the slow
        streak has exhausted patience (the mitigation trigger)."""
        baseline = self.ewma.value
        slow = baseline is not None and \
            seconds_per_token > self.slow_factor * baseline
        if slow:
            self.strikes += 1
            # a slow sample does NOT pollute the baseline: the EWMA tracks
            # healthy service time, the thing slowness is measured against
        else:
            self.strikes = 0
            self.ewma.observe(seconds_per_token)
        if slow and self.strikes >= self.patience:
            self.flagged_chunks += 1
            return True
        return False


class FaultInjector:
    """The seeded schedule, evaluated at the engine's device-call
    boundary. The engine calls `before(kind)` inside its retry loop:
    it may stall the clock (slow draw / nominal service time) and may
    raise TransientDeviceError (fault draw, for the site's first
    `transient_tries` attempts)."""

    def __init__(self, config: ChaosConfig, clock=None):
        self.config = config
        self.clock = clock
        self._calls: dict[str, int] = {}       # kind -> next call index
        self._pending_tries: dict[tuple[str, int], int] = {}
        self._sdc_calls: dict[str, int] = {}   # kind -> next SDC site index
        self._pending_sdc: dict[tuple[str, int], list] = {}
        self.injected = {"faults": 0, "slow": 0, "calls": 0, "sdc": 0}

    def _draw(self, kind: str, index: int) -> random.Random:
        # seed with a STRING: random.Random hashes str/bytes stably
        # (sha512-based), while tuples go through hash(), which is
        # randomized per process for the embedded str — the schedule must
        # be byte-identical across runs and boxes
        return random.Random(f"{self.config.seed}:{kind}:{index}")

    def _stall(self, seconds: float) -> None:
        if seconds > 0 and self.clock is not None and \
                hasattr(self.clock, "sleep"):
            self.clock.sleep(seconds)

    def before(self, kind: str) -> None:
        """One attempt of one device call of `kind` ("prefill"/"decode").
        A new call site is drawn once; its verdict is replayed across the
        engine's retry attempts so `transient_tries` failures are
        consecutive, then the site heals."""
        site = (kind, self._calls.get(kind, 0))
        tries = self._pending_tries.get(site)
        if tries is None:                      # first attempt: draw fate
            rng = self._draw(kind, site[1])
            faulty = rng.random() < self.config.p_fault
            slow = rng.random() < self.config.p_slow
            tries = self.config.transient_tries if faulty else 0
            self._pending_tries[site] = tries
            self._stall(self.config.service_seconds *
                        (self.config.slow_factor if slow else 1.0))
            self.injected["calls"] += 1
            if slow:
                self.injected["slow"] += 1
        if tries > 0:
            self._pending_tries[site] = tries - 1
            self.injected["faults"] += 1
            raise TransientDeviceError(
                f"injected transient fault ({kind} call {site[1]}, "
                f"{tries - 1} more before heal)")
        # attempt succeeds: the site is consumed
        del self._pending_tries[site]
        self._calls[kind] = site[1] + 1

    def sdc_plan(self, kind: str) -> Optional[tuple[int, int, int]]:
        """One attempt's silent-corruption verdict: an int plan
        ``(target_gemm, draw_seed, n_elems)`` for the guarded GEMM path
        (guard.inject_sdc), or None for a clean attempt.

        Sites mirror the transient discipline: a site drawn corrupt
        replays the SAME plan (same draw_seed) for ``transient_tries``
        consecutive attempts — so the engine's recompute-and-retry sees
        a persistent flip until the site heals — then the next attempt
        runs clean and consumes the site. Unlike `before`, corruption is
        discovered AFTER the call succeeds, so the site is keyed by its
        own per-kind counter that only advances on a clean attempt."""
        if self.config.p_sdc <= 0.0:
            return None
        idx = self._sdc_calls.get(kind, 0)
        site = (kind, idx)
        st = self._pending_sdc.get(site)
        if st is None:                         # first attempt: draw fate
            rng = self._draw(f"sdc-{kind}", idx)
            hit = rng.random() < self.config.p_sdc
            st = [self.config.transient_tries if hit else 0,
                  rng.randrange(1 << 31)]
            self._pending_sdc[site] = st
        if st[0] > 0:
            st[0] -= 1
            self.injected["sdc"] += 1
            return (self.config.sdc_target, st[1], self.config.sdc_elems)
        # clean attempt: the site heals and is consumed
        del self._pending_sdc[site]
        self._sdc_calls[kind] = idx + 1
        return None
