"""Batched serving engine: continuous batching over prefill + decode.

The engine owns a fixed decode batch of `slots`; requests queue, prefill
into a free slot's cache lane, and decode step-locked with the rest of the
batch (the standard continuous-batching pattern). Per-slot caches live in
one batched cache pytree — slot insertion is a dynamic_update along the
batch axis, so the whole engine is jit-compatible and shardable (batch axis
over the DP mesh axes).

SOSA tie-in (§6.1 multi-tenancy): co-scheduling independent request
streams is exactly the paper's multi-tenant utilization argument — decode
GEMVs from many requests fuse into one batched GEMM, raising tiles/pod.
Pass `tracer=tenancy.ServeTraceRecorder()` to record the engine's actual
prefill/decode timeline; `tenancy/trace.py` lowers it to a GemmSpec tenant
for the co-schedule planner (tenancy/planner.py), and
`benchmarks/multitenancy.py` quantifies the co-scheduling gain with the
simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 512, src_len: int = 0,
                 eos_id: Optional[int] = None, tracer=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # optional duck-typed event sink (tenancy.ServeTraceRecorder): gets
        # on_prefill(rid, prompt_len) / on_decode(lanes, contexts) in the
        # engine's step-locked order
        self.tracer = tracer
        self.cache = model.init_cache(slots, max_len, src_len=src_len)
        self.active: list[Optional[Request]] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.budgets = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(model.decode_step)

    # -- request flow --------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request) -> None:
        """Prefill a single request into one slot lane of the batched cache
        (single-lane prefill batch; production would group same-length
        prompts — the batching policy is orthogonal to the cache layout)."""
        S = len(req.prompt)
        if self.tracer is not None:
            self.tracer.on_prefill(req.rid, S)
        lane_cache = self.model.init_cache(1, self.max_len)
        logits, lane_cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(req.prompt)[None, :]},
            lane_cache)
        self.cache = _write_lane(self.cache, lane_cache, slot)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.active[slot] = req
        self.positions[slot] = S
        self.budgets[slot] = req.max_new_tokens - 1

    # -- decode loop -----------------------------------------------------
    def step(self) -> int:
        """One step-locked decode over all active slots. Returns #active."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        if self.tracer is not None:
            self.tracer.on_decode(len(live),
                                  [int(self.positions[i]) for i in live])
        toks = np.zeros(self.slots, np.int32)
        for i in live:
            toks[i] = self.active[i].out[-1]
        # per-lane positions: mixed-length requests decode together, each
        # lane masked by its own cache length (continuous batching)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.positions))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in live:
            r = self.active[i]
            tok = int(nxt[i])
            r.out.append(tok)
            self.positions[i] += 1
            self.budgets[i] -= 1
            if self.budgets[i] <= 0 or (self.eos_id is not None
                                        and tok == self.eos_id):
                r.done = True
                self.active[i] = None
        return len(live)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not any(self.active):
                return
            self.step()


def _write_lane(batched_cache, lane_cache, slot: int):
    """Insert a 1-lane cache into slot `slot` of the batched cache.

    Both trees have identical structure; lane arrays have batch dim 1. The
    batch axis position differs by cache kind: stacked-layer caches are
    [L, B, ...], unstacked [B, ...] — detected from rank difference."""
    def ins(big, small):
        if small.shape == big.shape:
            return small
        # find the axis where big has `slots` and small has 1 (batch axis;
        # includes the per-lane length vectors [B] / [L, B])
        for ax in range(small.ndim):
            if small.shape[ax] == 1 and big.shape[ax] != 1:
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=ax)
        return big
    return jax.tree.map(ins, batched_cache, lane_cache)
