"""Batched serving engine: continuous batching with an on-device hot loop.

The engine owns a fixed decode batch of `slots`; requests queue, prefill
into a free slot's cache lane, and decode step-locked with the rest of the
batch. Two optimizations move the hot loop on-device (seed behavior is
preserved bit-for-bit in serve/reference.py as the oracle):

  * **Bucketed prefill** — prompts are right-padded to power-of-two length
    buckets, and queued requests of the same bucket batch into ONE prefill
    call over a fixed `slots`-lane batch. The jit cache is therefore
    bounded by the number of buckets (<= log2(max_len) variants) instead of
    one entry per distinct prompt length. Padding is inert for
    attention-only caches: causal masking keeps padded positions out of
    real positions' math, and a post-prefill length fixup masks the padded
    cache slots until decode overwrites them. Stateful mixers (SSM, ring
    buffers) join the bucket path via masked state updates driven by the
    per-lane true lengths (dt-masked SSD recurrence, true-length conv
    window, per-lane ring slot gather — see Model.forward(true_lens=...)).
    Models whose prefill genuinely can't share a padded batch (MoE
    capacity displacement, encoder-decoder/VLM non-token inputs) fall
    back to exact-length prefill (see Model.bucketed_prefill_ok).

  * **Fused multi-token decode** — a `lax.scan` of up to `decode_chunk`
    decode steps runs in one device call, carrying tokens / positions /
    budgets / EOS-alive masks as device arrays. The host syncs once per
    chunk (the admission boundary), not once per token. Chunk lengths are
    floored to powers of two so the decode jit cache stays bounded by
    log2(decode_chunk) variants. When the queue is non-empty the chunk is
    sized to the soonest-finishing lane so freed slots admit promptly;
    when the queue is drained, to the latest-finishing lane.

SOSA tie-in (§6.1 multi-tenancy): co-scheduling independent request
streams is exactly the paper's multi-tenant utilization argument — decode
GEMVs from many requests fuse into one batched GEMM, raising tiles/pod
(and with Model(use_pallas=True) they literally execute as one fused-lane
pod GEMM, kernels/systolic_gemm). Pass
`tracer=tenancy.ServeTraceRecorder()` to record the engine's actual
prefill/decode timeline; events are emitted in the same step-locked order
as the seed engine (decode events are reconstructed per scan step from the
chunk's emit masks), so `tenancy/trace.py` lowers them unchanged.

Overload & failure semantics (serve/admission.py, serve/chaos.py): every
submitted request reaches exactly one terminal state — ``done`` |
``rejected`` | ``expired`` — and malformed requests raise
`InvalidRequest` at submit. `admission=` selects the policy (fifo | edf |
slo-aware: deadline ordering, bounded-queue backpressure, wave-model
predictive shedding, overload budget degradation); deadline expiry runs
at the existing per-chunk host sync (zero new syncs). `chaos=` injects a
seeded fault schedule at the device-call boundary: transient faults
retry with exponential backoff (`max_retries`, `backoff_s`) before the
affected requests are rejected with their slots reclaimed, and an EWMA
slow-chunk detector (train/fault.py machinery) halves the next chunk
while the device is degraded. With the defaults (fifo, unbounded, no
chaos, no deadlines) the hot loop is bit-identical to the seed: same
tokens, same jit cache sizes, same host-sync count (gated in
tests/test_serving.py and tests/test_admission.py).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.systolic_gemm.guard import GuardTape, as_guard
from ..models.attention import KVCache, PagedKVCache, RingKVCache
from ..models.model import CrossKV, Model
from ..models.ssm import SSMCache
from ..models.transformer import MLACache
from ..train.fault import Ewma
from .admission import (AdmissionConfig, AdmissionController, InvalidRequest,
                        NEW, SLO_AWARE, ServeStalled, WaveLatencyPredictor)
from .chaos import (FaultInjector, NumericalFault, PermanentFault,
                    SilentCorruption, SlowChunkDetector,
                    TransientDeviceError, check_lanes_finite)
from .paging import PagePool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # extra prefill-batch arrays (batch-dim included), e.g. whisper frames
    # {"frames": [1, src_len, d_model]} — merged into the prefill batch;
    # requests with extras always prefill exact-length (per-request shapes
    # can't join a shared bucket batch)
    extras: dict = dataclasses.field(default_factory=dict)
    # QoS envelope (serve/admission.py): deadline is seconds from submit
    # on the engine's clock; priority breaks deadline ties (lower = more
    # urgent). state walks new -> queued -> running -> one terminal state
    # (done | rejected | expired); reason says why a request was shed.
    deadline_s: Optional[float] = None
    priority: int = 0
    state: str = NEW
    reason: str = ""
    # stamped by the admission controller
    _seq: int = dataclasses.field(default=0, repr=False)
    _submit_t: float = dataclasses.field(default=0.0, repr=False)
    _admit_t: float = dataclasses.field(default=0.0, repr=False)
    _deadline: Optional[float] = dataclasses.field(default=None, repr=False)
    # jit cache sizes (prefill + decode) at admit time: a retire whose
    # epoch grew saw compile time inside its service wall — its κ
    # calibration sample is skipped (cold-start κ pollution bugfix)
    _jit_epoch: int = dataclasses.field(default=-1, repr=False)

    @property
    def finished(self) -> bool:
        return self.state in ("done", "rejected", "expired")


class ServeEngine:
    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 512, src_len: int = 0,
                 eos_id: Optional[int] = None, tracer=None,
                 decode_chunk: int = 8, prefill_buckets: bool = True,
                 min_bucket: int = 8, metrics=None, admission=None,
                 chaos=None, clock=None, max_retries: int = 3,
                 backoff_s: float = 1e-3, guard=None, paged: bool = False,
                 page_size: int = 16, kv_pages: Optional[int] = None,
                 recycle: Optional[bool] = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.src_len = src_len
        self.eos_id = eos_id
        # optional duck-typed event sink (tenancy.ServeTraceRecorder): gets
        # on_prefill(rid, prompt_len) / on_decode(lanes, contexts) in the
        # engine's step-locked order, and (if it defines on_span) one timed
        # span per device call for the Perfetto export (obs/export.py)
        self.tracer = tracer
        # optional obs.metrics.MetricsRegistry. Recording is host-side
        # bookkeeping on values the engine already has at each chunk
        # boundary: metrics-on adds no host syncs and no jit cache entries
        # (the device-side accumulators below run unconditionally), gated
        # by tests/test_serving.py.
        self.metrics = metrics
        self.decode_chunk = max(1, decode_chunk)
        self.min_bucket = max(1, min_bucket)
        self.bucketed = bool(prefill_buckets) and model.bucketed_prefill_ok
        # paged=True swaps every global-attention KVCache leaf for a
        # PagedKVCache over a shared kv_pages-page pool; serve/paging.py
        # owns the host-side allocator, riding the existing one-sync-per-
        # chunk boundary. paged=False keeps the hot loop bit-identical to
        # the dense engine (same arrays, same jit entries, same syncs).
        self._pool: Optional[PagePool] = None
        if paged:
            if not self.bucketed:
                raise ValueError(
                    "paged serving requires the bucketed prefill path "
                    "(dense/ssm/hybrid families with prefill_buckets=True)")
            if kv_pages is None:
                # default pool covers the dense worst case exactly; size
                # it down to oversubscribe (admission then queues on pages)
                kv_pages = slots * (max_len // page_size)
            self._pool = PagePool(kv_pages, page_size, slots, max_len,
                                  chunk_slack=self.decode_chunk)
            self.cache = model.init_cache(slots, max_len, src_len=src_len,
                                          page_size=page_size,
                                          kv_pages=kv_pages)
        else:
            self.cache = model.init_cache(slots, max_len, src_len=src_len)
        # in-chunk lane recycling: after the retires of a decode chunk,
        # re-run admission at the SAME host sync so a lane that died
        # mid-chunk hands its slot (and pages) to a queued request with no
        # intervening idle chunk. Default: on exactly when paged (the
        # extra admission pass changes chunk-length choices, which the
        # paged-off bit-identity gate forbids).
        self.recycle = bool(paged) if recycle is None else bool(recycle)
        self.recycled = 0
        self.active: list[Optional[Request]] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.budgets = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._buckets_seen: set[int] = set()
        self._batch_axes = self._probe_batch_axes()
        self._prefill_fn = jax.jit(self._prefill_paged_impl if paged
                                   else self._prefill_batched_impl)
        self._decode_fn = jax.jit(self._decode_chunk_impl,
                                  static_argnames=("n",))
        # injectable clock (serve/chaos.VirtualClock in tests/benchmarks);
        # everything time-dependent — spans, deadlines, backoff, EWMAs —
        # reads it, so failure scenarios replay deterministically
        self._clock = clock if clock is not None else time.perf_counter
        # admission policy: None/str/AdmissionConfig -> controller. The
        # default AdmissionConfig() is the seed engine exactly (fifo,
        # unbounded queue, no deadlines => no controller interference).
        if admission is None:
            admission = AdmissionConfig()
        elif isinstance(admission, str):
            admission = AdmissionConfig(policy=admission)
        predictor = None
        if isinstance(admission, AdmissionConfig):
            if admission.policy == SLO_AWARE:
                predictor = WaveLatencyPredictor(
                    model.cfg, admission.design, admission.tdp,
                    faulty_pods=admission.faulty_pods)
            admission = AdmissionController(
                admission, slots=slots, max_len=max_len,
                predictor=predictor, metrics=metrics)
        self.admission: AdmissionController = admission
        if self._pool is not None:
            # paged admission: free pages, not free slots, are the gating
            # resource — the controller rejects can-never-fit requests at
            # submit and (slo-aware) sheds on predicted page exhaustion
            self.admission.attach_pool(self._pool)
        # chaos: a ChaosConfig arms the seeded fault injector plus the
        # EWMA slow-chunk detector; None (default) leaves the hot loop
        # untouched (no per-call hooks at all)
        if chaos is not None and not isinstance(chaos, FaultInjector):
            chaos = FaultInjector(chaos, clock=clock)
        self._chaos: Optional[FaultInjector] = chaos
        self._slow_detect = SlowChunkDetector() if chaos is not None \
            else None
        # SDC guard (kernels/systolic_gemm/guard.py): None/"off" keeps the
        # hot loop bit-identical to an unguarded build; "probe"/"abft"
        # wrap the jitted bucketed-prefill and fused-decode impls in a
        # GuardTape so every pod GEMM is verified (and, under abft,
        # single corruptions repaired in-graph). The exact-length prefill
        # fallback stays outside the guard envelope (its model.prefill
        # jit cache would skip the tape's trace-time hooks on a hit).
        self._guard = as_guard(guard)
        self._guard_on = self._guard.mode != "off"
        self._sdc_plan = None         # armed per attempt by _device_call
        self._sdc_magnitude = (self._chaos.config.sdc_magnitude
                               if self._chaos is not None else 1e4)
        # host-side guard tallies (mirrored to metrics when enabled)
        self.guard_events = {"corrected": 0, "uncorrectable": 0,
                             "non_finite": 0}
        self._chunk_cap: Optional[int] = None
        self.max_retries = max(0, int(max_retries))
        self.backoff_s = float(backoff_s)
        # measured decode seconds/token (host floats, always cheap): the
        # deadline-aware chunk capping below sizes chunks with it
        self._sec_per_tok = Ewma(alpha=0.3)
        self._t0 = self._clock()

    # -- fault boundary -------------------------------------------------
    def _sleep(self, seconds: float) -> None:
        if seconds <= 0:
            return
        if hasattr(self._clock, "sleep"):
            self._clock.sleep(seconds)        # virtual time: no blocking
        else:
            time.sleep(seconds)

    def _device_call(self, kind: str, fn):
        """Run one device call through the fault boundary: the chaos
        injector may stall or raise per its seeded schedule; transient
        errors retry with exponential backoff up to `max_retries`, then
        escalate to PermanentFault. A guard-enabled `fn` additionally
        syncs its verdict flags and raises SilentCorruption on detected-
        but-uncorrected output — retried identically (recompute usually
        clears a transient flip; the injector replays a corrupt site for
        `transient_tries` attempts before it heals), but exhaustion
        re-raises SilentCorruption so the caller finalizes the lanes as
        ``sdc-uncorrectable`` instead of ``device-fault``. Results are
        returned (never assigned to engine state here), so a failed call
        leaves cache/lanes exactly as they were. With chaos disarmed and
        guard off this is a plain call."""
        if self._chaos is None and not self._guard_on:
            return fn()
        attempt = 0
        while True:
            try:
                if self._chaos is not None:
                    self._chaos.before(kind)
                    self._sdc_plan = (self._chaos.sdc_plan(kind)
                                      if self._guard_on else None)
                return fn()
            except (TransientDeviceError, SilentCorruption) as err:
                attempt += 1
                if self.metrics is not None:
                    self.metrics.counter("serve.chaos.retries",
                                         kind=kind).inc()
                if attempt > self.max_retries:
                    if isinstance(err, SilentCorruption):
                        raise
                    raise PermanentFault(
                        f"{kind} device call failed after {attempt} "
                        f"attempts: {err}") from err
                self._sleep(self.backoff_s * (2 ** (attempt - 1)))

    def _reject_group(self, reqs: list, reason: str) -> None:
        for r in reqs:
            self.admission.reject(r, reason)
        if self.metrics is not None:
            name = ("serve.chaos.sdc_uncorrectable"
                    if reason == "sdc-uncorrectable"
                    else "serve.chaos.permanent_faults")
            self.metrics.counter(name).inc()

    def _sdc_arr(self):
        """The attempt's injection plan as the traced int32[3] the guarded
        impls consume; (-1, 0, 0) disarms (no chaos / clean draw)."""
        plan = self._sdc_plan if self._sdc_plan is not None else (-1, 0, 0)
        return jnp.asarray(plan, jnp.int32)

    def _note_guard(self, corrected: int) -> None:
        if corrected > 0:
            self.guard_events["corrected"] += int(corrected)
            if self.metrics is not None:
                self.metrics.counter("serve.guard.corrected").inc(
                    int(corrected))

    def _shed_non_finite(self, pairs: list, where: str) -> None:
        """Finalize lanes whose logits went NaN/Inf: the typed
        NumericalFault is raised (check_lanes_finite) and caught at this
        boundary — recompute would return the same poison, so there is no
        retry; each affected request ends ``rejected`` with terminal
        reason ``non-finite-logits`` and everyone else keeps serving."""
        try:
            check_lanes_finite([(lane, True) for _, lane in pairs], where)
        except NumericalFault as err:
            for (r, _), lane in zip(pairs, err.lanes):
                self.admission.reject(r, "non-finite-logits")
            self.guard_events["non_finite"] += len(pairs)
            if self.metrics is not None:
                self.metrics.counter("serve.numerical_faults",
                                     where=where).inc(len(pairs))

    # -- telemetry ------------------------------------------------------
    def _span(self, name: str, cat: str, t_start: float, t_end: float,
              **args) -> None:
        """Emit a timed span to the tracer (engine-relative wall clock);
        no-op unless the tracer understands spans (on_span)."""
        if self.tracer is not None and hasattr(self.tracer, "on_span"):
            self.tracer.on_span(name, ts=t_start - self._t0,
                                dur=t_end - t_start, cat=cat, **args)

    def _observe_prefill(self, path: str, tokens: int, lanes: int,
                        seconds: float) -> None:
        m = self.metrics
        if m is None:
            return
        m.counter("serve.prefill.calls", path=path).inc()
        m.counter("serve.prefill.tokens").inc(tokens)
        m.counter("serve.prefill.seconds").inc(seconds)
        m.histogram("serve.prefill.us").record(seconds * 1e6)
        m.gauge("serve.prefill.lanes").set(lanes)
        m.gauge("serve.queue_depth").set(len(self.queue))

    def _observe_decode(self, n: int, lanes: int, emitted: int,
                        live_end: int, seconds: float) -> None:
        m = self.metrics
        if m is None:
            return
        m.counter("serve.decode.chunks").inc()
        m.counter("serve.decode.tokens").inc(emitted)
        m.counter("serve.decode.seconds").inc(seconds)
        m.histogram("serve.decode.chunk_len").record(n)
        m.gauge("serve.slot_occupancy").set(lanes / self.slots)
        m.gauge("serve.decode.live_lanes_end").set(live_end)
        m.gauge("serve.queue_depth").set(len(self.queue))
        if emitted:
            # honest next-token wait: every token delivered at this chunk's
            # host sync waited the chunk's full wall time (the p50/p99 the
            # serving benchmark reports, now live)
            m.histogram("serve.decode.token_wait_us").record(
                seconds * 1e6, n=emitted)
        tok = m.counter("serve.decode.tokens").value
        sec = m.counter("serve.decode.seconds").value
        if sec > 0:
            m.gauge("serve.decode.tok_s").set(tok / sec)

    # -- request flow --------------------------------------------------
    def submit(self, req: Request) -> None:
        """Validate + enqueue. Raises InvalidRequest (typed, names the
        offending field) for malformed requests; under a bounded queue the
        admission policy may shed (request ends ``rejected``, reason
        ``queue-full`` / ``shed-predicted-miss``) instead of enqueueing."""
        if self._pool is not None and req.extras:
            raise InvalidRequest(
                "extras", "paged serving cannot prefill per-request extra "
                "modalities (exact-length fallback is dense-only)")
        if self.admission.on_submit(self.queue, req, self._clock()):
            self.queue.append(req)
        if self.metrics is not None:
            self.metrics.gauge("serve.queue_depth").set(len(self.queue))

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _bucket(self, prompt_len: int) -> int:
        b = max(self.min_bucket, prompt_len)
        b = 1 << (b - 1).bit_length()                # next power of two
        return min(b, self.max_len)

    def _admit(self) -> None:
        # queue sweep first: expire queued-past-deadline, shed predicted
        # misses (slo-aware), and order the queue per policy. Pure host
        # work; a fifo queue with no deadlines passes through untouched.
        self.admission.sweep(self.queue, self._clock())
        while self.queue:
            free = self._free_slots()
            if not free:
                return
            if not self.bucketed or self.queue[0].extras:
                # extras carry per-request shapes (e.g. frames) that can't
                # join a shared bucket batch: prefill them exact-length
                self._prefill_into(free[0], self.queue.pop(0))
                continue
            # group the head-of-queue bucket: every queued request of the
            # same bucket rides the same prefill call (up to free slots)
            b = self._bucket(len(self.queue[0].prompt))
            take: list[Request] = []
            rest: list[Request] = []
            for r in self.queue:
                if len(take) < len(free) and not r.extras and \
                        self._bucket(len(r.prompt)) == b:
                    if self._pool is not None:
                        # paged admission: a lane starts only if its
                        # worst-case page count (prompt + clamped budget +
                        # one chunk of inert-write slack) reserves now —
                        # the per-chunk mapping then can never fail.
                        # Requests that don't fit wait queued for pages.
                        worst = self._pool.worst_pages(
                            len(r.prompt), self._clamped_budget(r))
                        if not self._pool.can_reserve(worst):
                            rest.append(r)
                            continue
                        self._pool.reserve(free[len(take)], worst)
                    take.append(r)
                else:
                    rest.append(r)
            self.queue = rest
            if not take:
                # head bucket blocked on pages this quantum; retires at
                # the next chunk sync will free some
                return
            self._prefill_group(take, free[: len(take)], b)

    # -- bucketed prefill ------------------------------------------------
    def _probe_batch_axes(self):
        """Per-leaf batch axis of the cache pytree, found by diffing a
        2-lane cache against a 1-lane cache (static metadata; makes lane
        insertion exact instead of shape-guessed). Probed from throwaway
        trees, never self.cache: the batch axis doesn't depend on the
        engine's slot count, and a slots==1 engine has no size difference
        of its own to diff (assuming axis 0 there scattered stacked-layer
        leaves — length [L, B], k [L, B, T, H, D] — along the LAYER axis,
        silently zeroing every layer past the first)."""
        # always probed from DENSE trees: the paged prefill runs its
        # forward over a dense transient lane cache, so the axes tree must
        # mirror that structure (the pool-shaped leaves never need axes)
        big = self.model.init_cache(2, self.max_len, src_len=self.src_len)
        ref1 = self.model.init_cache(1, self.max_len, src_len=self.src_len)

        def axis(b, small):
            for ax in range(b.ndim):
                if b.shape[ax] != small.shape[ax]:
                    return ax
            return 0
        return jax.tree.map(axis, big, ref1)

    def _prefill_group(self, reqs: list[Request], slot_list: list[int],
                       bucket: int) -> None:
        toks = np.zeros((self.slots, bucket), np.int32)
        true_lens = np.ones(self.slots, np.int32)      # pad lanes: len 1
        slot_ids = np.full(self.slots, -1, np.int32)
        for g, (r, s) in enumerate(zip(reqs, slot_list)):
            S = len(r.prompt)
            toks[g, :S] = r.prompt
            true_lens[g] = S
            slot_ids[g] = s
        args = [jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(true_lens)]
        if self._pool is not None:
            # map each lane's prompt pages, then hand the impl a LANE-
            # indexed destination table (row g = lane g's pages, sentinel-
            # padded) for the page-granular scatter. The slot-indexed
            # device page_table is pushed separately before the next
            # decode chunk (step() checks pool.dirty).
            dest = np.full((self.slots, self._pool.pages_per_lane),
                           self._pool.sentinel, np.int32)
            for g, (r, s) in enumerate(zip(reqs, slot_list)):
                self._pool.map_to(s, len(r.prompt))
                own = self._pool.owned(s)
                dest[g, :len(own)] = own
            args.append(jnp.asarray(dest))
        self._buckets_seen.add(bucket)
        t_start = self._clock()
        try:
            if self._guard_on:
                def call():
                    first, cache, gstats = self._prefill_fn(
                        self.params, args[0], self.cache, *args[1:],
                        self._sdc_arr())
                    flags = np.asarray(gstats)
                    if int(flags[1]) > 0:
                        raise SilentCorruption(
                            f"prefill: {int(flags[1])} uncorrected "
                            f"corruption(s) detected")
                    return first, cache, int(flags[0])
                first, cache, corrected = self._device_call("prefill", call)
                self._note_guard(corrected)
            else:
                first, cache = self._device_call(
                    "prefill", lambda: self._prefill_fn(
                        self.params, args[0], self.cache, *args[1:]))
        except PermanentFault:
            # the whole group failed before any state was assigned: shed
            # the requests (terminal `rejected`), slots stay free and
            # their page reservations return to the pool
            self._reject_group(reqs, "device-fault")
            self._release_group(slot_list, len(reqs))
            return
        except SilentCorruption:
            self.guard_events["uncorrectable"] += 1
            self._reject_group(reqs, "sdc-uncorrectable")
            self._release_group(slot_list, len(reqs))
            return
        self.cache = cache
        first = np.asarray(first)
        t_end = self._clock()
        if self.tracer is not None:
            for r in reqs:       # successful work only enters the trace
                self.tracer.on_prefill(r.rid, len(r.prompt),
                                       t=t_start - self._t0)
        n_tokens = int(sum(len(r.prompt) for r in reqs))
        self._span(f"prefill/bucket{bucket}", "prefill", t_start, t_end,
                   bucket=bucket, lanes=len(reqs), tokens=n_tokens,
                   rids=[r.rid for r in reqs])
        self._observe_prefill("bucketed", n_tokens, len(reqs),
                              t_end - t_start)
        # a lane whose prefill logits were non-finite is encoded as a -1
        # first token (impl below) — shed it before the slot is activated
        poisoned = [(r, s) for g, (r, s) in enumerate(zip(reqs, slot_list))
                    if first[g] < 0]
        if poisoned:
            self._shed_non_finite(poisoned, where="prefill")
            if self._pool is not None:
                for _, s in poisoned:    # slot never activated: free pages
                    self._pool.release(s, now=self._clock())
        for g, (r, s) in enumerate(zip(reqs, slot_list)):
            if first[g] < 0:
                continue
            r.out.append(int(first[g]))
            self.active[s] = r
            self.positions[s] = len(r.prompt)
            self.budgets[s] = self.admission.clamp_budget(
                r, self._clamped_budget(r), len(self.queue))
            self.admission.note_admitted(r, t_end)
            r._jit_epoch = self._jit_sizes()
            self._retire_if_full(s)

    def _prefill_forward(self, params, tokens, true_lens, sdc):
        """Shared body of both prefill impls: forward over a dense
        transient lane cache, per-lane last-real-position logits, length
        fixup. A lane with non-finite last-position logits encodes its
        first token as -1 — same arrays, same syncs as the healthy path.
        With the guard on, the forward runs under a GuardTape (every pod
        GEMM verified; `sdc` is the traced injection plan) and the tape
        totals become an extra output riding the existing sync."""
        lane_cache = self.model.init_cache(self.slots, self.max_len,
                                           src_len=self.src_len)
        # true_lens drives the stateful families' masked state updates
        # (SSM dt-masking + conv window, ring slot gather); attention-only
        # caches ignore it and rely on the _fix_lengths fixup below
        if self._guard_on:
            with GuardTape(self._guard, inject=sdc,
                           magnitude=self._sdc_magnitude) as tape:
                logits, lane_cache = self.model.forward(
                    params, {"tokens": tokens}, cache=lane_cache,
                    true_lens=true_lens)
            gstats = jnp.stack(tape.totals())
        else:
            logits, lane_cache = self.model.forward(params, {"tokens": tokens},
                                                    cache=lane_cache,
                                                    true_lens=true_lens)
            gstats = None
        idx = jnp.maximum(true_lens - 1, 0)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
        first_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        first_tok = jnp.where(jnp.isfinite(last).all(axis=-1), first_tok,
                              jnp.int32(-1))
        return first_tok, _fix_lengths(lane_cache, true_lens), gstats

    def _prefill_batched_impl(self, params, tokens, big_cache, slot_ids,
                              true_lens, sdc=None):
        """One jitted prefill over a fixed [slots, bucket] token batch:
        forward (see _prefill_forward) then scatter of each real lane into
        its slot of the batched cache. Compiles once per bucket (tokens'
        trailing dim is the only varying shape)."""
        first_tok, lane_cache, gstats = self._prefill_forward(
            params, tokens, true_lens, sdc)
        cache = big_cache
        for g in range(self.slots):                   # static unroll
            valid = slot_ids[g] >= 0
            slot = jnp.maximum(slot_ids[g], 0)
            cache = jax.tree.map(
                lambda big, lane, ax, v=valid, s=slot, g=g: jnp.where(
                    v,
                    jax.lax.dynamic_update_slice_in_dim(
                        big,
                        jax.lax.dynamic_slice_in_dim(lane, g, 1, axis=ax
                                                     ).astype(big.dtype),
                        s, axis=ax),
                    big),
                cache, lane_cache, self._batch_axes)
        if self._guard_on:
            return first_tok, cache, gstats
        return first_tok, cache

    def _prefill_paged_impl(self, params, tokens, big_cache, slot_ids,
                            true_lens, dest_pages, sdc=None):
        """Paged twin of _prefill_batched_impl: the identical forward over
        a dense transient lane cache, then a page-granular scatter of the
        attention KV into the pool (dest_pages: lane-indexed page rows the
        host allocator chose, sentinel-padded) while lane-resident state
        (SSM, ring windows) takes the same per-slot dense scatter as the
        dense impl. Still compiles once per bucket."""
        first_tok, lane_cache, gstats = self._prefill_forward(
            params, tokens, true_lens, sdc)
        cache = _paged_insert(big_cache, lane_cache, self._batch_axes,
                              slot_ids, true_lens, dest_pages, self.slots)
        if self._guard_on:
            return first_tok, cache, gstats
        return first_tok, cache

    # -- exact-length prefill (SSM / ring / cross / MoE families) --------
    def _prefill_into(self, slot: int, req: Request) -> None:
        """Prefill a single request into one slot lane of the batched
        cache. The lane cache is built with the engine's src_len so
        encoder-decoder cross-KV lanes line up with the batched cache
        (regression: the seed dropped src_len here)."""
        S = len(req.prompt)
        self._buckets_seen.add(S)     # exact-length path: one shape per len
        t_start = self._clock()
        lane_cache = self.model.init_cache(1, self.max_len,
                                           src_len=self.src_len)
        batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
        for key, val in req.extras.items():
            batch[key] = jnp.asarray(val)
        try:
            logits, lane_cache = self._device_call(
                "prefill",
                lambda: self.model.prefill(self.params, batch, lane_cache))
        except PermanentFault:
            self._reject_group([req], "device-fault")
            return
        # fold the finiteness check into the one value already synced:
        # a poisoned lane yields -1 and is shed before slot activation
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        first = int(jnp.where(jnp.isfinite(logits[0]).all(), first, -1))
        if first < 0:
            self._shed_non_finite([(req, slot)], where="prefill")
            return
        self.cache = _write_lane(self.cache, lane_cache, slot)
        req.out.append(first)
        t_end = self._clock()
        if self.tracer is not None:
            self.tracer.on_prefill(req.rid, S, t=t_start - self._t0)
        self._span(f"prefill/exact{S}", "prefill", t_start, t_end,
                   bucket=S, lanes=1, tokens=S, rids=[req.rid])
        self._observe_prefill("exact", S, 1, t_end - t_start)
        self.active[slot] = req
        self.positions[slot] = S
        self.budgets[slot] = self.admission.clamp_budget(
            req, self._clamped_budget(req), len(self.queue))
        self.admission.note_admitted(req, t_end)
        req._jit_epoch = self._jit_sizes()
        self._retire_if_full(slot)

    def _clamped_budget(self, req: Request) -> int:
        """Decode steps this request may take: its budget, clamped so the
        lane never appends past max_len (an oversized request degrades to
        a shorter completion instead of silently rewriting its last KV
        slot)."""
        return min(req.max_new_tokens - 1,
                   max(0, self.max_len - len(req.prompt)))

    def _retire_if_full(self, slot: int) -> None:
        """A prompt that fills the cache leaves no room for even the one
        forced decode step of a budget-0 lane — retire it with just the
        prefill token instead of letting the append clobber the last KV
        slot."""
        if self.positions[slot] >= self.max_len:
            self.admission.finish(self.active[slot], now=self._clock())
            self._release_slot(slot)

    def _release_slot(self, i: int) -> None:
        """Clear a lane AND return its pages — the single retirement path
        for every way a lane can die (finish, expiry, shed, device fault),
        so chaos can never leak pages."""
        if self._pool is not None:
            self._pool.release(i, now=self._clock())
        self.active[i] = None

    def _release_group(self, slot_list: list[int], n: int) -> None:
        if self._pool is not None:
            for s in slot_list[:n]:
                self._pool.release(s, now=self._clock())

    def _jit_sizes(self) -> int:
        """Combined prefill+decode jit cache entry count — the jit-epoch
        stamp for the cold-start κ fix (a service interval that saw ANY
        compile, its own or a co-resident lane's, is not a clean sample)."""
        total = 0
        for fn in (self._prefill_fn, self._decode_fn):
            try:
                total += int(fn._cache_size())
            except AttributeError:                    # pragma: no cover
                return -2     # can't tell -> epochs never match, skip all
        return total

    # -- fused decode loop ------------------------------------------------
    def _decode_chunk_impl(self, params, cache, toks, pos, bud, alive,
                           sdc=None, *, n: int):
        """n fused decode steps as one lax.scan on device. Carries the
        batched cache + per-lane (token, position, budget, alive) vectors;
        emits the per-step greedy tokens and emit masks, plus the chunk's
        telemetry accumulators (emitted-token total and live-lane count at
        chunk end) carried on device and drained with the chunk's one host
        sync — metrics read them for free, so metrics-on adds no syncs.
        A lane whose budget runs out (or that hits eos) drops out of the
        emit mask but keeps decoding inertly until the chunk ends — its
        slot is freed at the next admission boundary and prefill fully
        rewrites the lane.

        Always-on numerical guard: a lane whose logits go NaN/Inf stops
        emitting at that step and sets its flag in the stats vector (the
        flags ride the existing stats sync — zero new syncs; a healthy
        lane's tokens are untouched). With the PodGuard on, each scan
        step's model call runs under a GuardTape — the scan body traces
        once, so an armed `sdc` plan corrupts its target GEMM every step
        of the chunk — and the (corrected, uncorrected) totals join the
        stats vector."""
        eos = self.eos_id
        guard_on = self._guard_on

        def step(carry, _):
            cache, toks, pos, bud, alive, emitted, bad, gcorr, gunc = carry
            if guard_on:
                with GuardTape(self._guard, inject=sdc,
                               magnitude=self._sdc_magnitude) as tape:
                    logits, cache = self.model.decode_step(params, toks,
                                                           cache, pos)
                corr, unc = tape.totals()
                gcorr, gunc = gcorr + corr, gunc + unc
            else:
                logits, cache = self.model.decode_step(params, toks, cache,
                                                       pos)
            ok = jnp.isfinite(logits).all(axis=-1)
            bad = bad | (alive & ~ok)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            emit = alive & ok
            toks = jnp.where(emit, nxt, toks)
            bud = bud - emit.astype(bud.dtype)
            done = bud <= 0
            if eos is not None:
                done = done | (nxt == eos)
            alive = alive & ~done & ok
            pos = pos + 1
            emitted = emitted + emit.sum(dtype=jnp.int32)
            return (cache, toks, pos, bud, alive, emitted, bad,
                    gcorr, gunc), (toks, emit)

        carry0 = (cache, toks, pos, bud, alive, jnp.int32(0),
                  jnp.zeros(self.slots, bool), jnp.int32(0), jnp.int32(0))
        (cache, _, _, _, alive, emitted, bad, gcorr, gunc), (seq, emits) = \
            jax.lax.scan(step, carry0, None, length=n)
        parts = [jnp.stack([emitted, alive.sum(dtype=jnp.int32)]),
                 bad.astype(jnp.int32)]
        if guard_on:
            parts.append(jnp.stack([gcorr, gunc]))
        stats = jnp.concatenate(parts)
        return cache, seq, emits, stats

    def _chunk_len(self, live: list[int]) -> int:
        # queue waiting -> sync at the soonest lane completion (admit
        # early); queue drained -> run to the latest lane (fewest syncs)
        rem = [max(1, int(self.budgets[i])) for i in live]
        need = min(rem) if self.queue else max(rem)
        room = min(int(self.max_len - self.positions[i]) for i in live)
        n = max(1, min(self.decode_chunk, need, max(1, room)))
        if self._chunk_cap is not None:
            # slow-chunk mitigation (chaos armed + detector flagged):
            # shorter chunks while the device is degraded, so deadline
            # checks and admission come around sooner
            n = min(n, self._chunk_cap)
        deadlines = [self.active[i]._deadline for i in live
                     if self.active[i]._deadline is not None]
        spt = self._sec_per_tok.value
        if deadlines and spt is not None and spt > 0:
            # deadline-aware sizing: don't run a chunk so long the
            # earliest-deadline lane blows through its deadline between
            # host syncs. Only lanes with deadlines trigger this — the
            # bare fifo path is untouched (same chunk sizes as the seed).
            slack = min(deadlines) - self._clock()
            if slack <= 0:
                n = 1                 # sync asap; expiry reclaims the lane
            else:
                n = max(1, min(n, int(slack / spt)))
        # pow2 floor: <= log2(decode_chunk)+1 compiled chunk variants
        return 1 << (n.bit_length() - 1)

    def step(self) -> int:
        """One scheduling quantum: admission, then one fused decode chunk.
        Returns the number of lanes live at the chunk start."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        n = self._chunk_len(live)
        if self._pool is not None:
            # map pages to cover this chunk's appends (live lanes reach
            # pos+n; a lane that dies mid-chunk writes inertly inside the
            # same bound — covered by its reservation's chunk slack), then
            # push the refreshed slot-indexed table if anything changed.
            # Host-side work + one async host->device transfer: no syncs.
            for i in live:
                self._pool.map_to(i, int(self.positions[i]) + n)
            if self._pool.dirty:
                self.cache = self._with_table(self.cache)
        toks = np.zeros(self.slots, np.int32)
        alive0 = np.zeros(self.slots, bool)
        for i in live:
            toks[i] = self.active[i].out[-1]
            alive0[i] = True
        pos0 = self.positions.copy()
        t_start = self._clock()
        try:
            if self._guard_on:
                def call():
                    cache, seq, emits, stats = self._decode_fn(
                        self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray(pos0), jnp.asarray(self.budgets),
                        jnp.asarray(alive0), self._sdc_arr(), n=n)
                    flags = np.asarray(stats)
                    if int(flags[-1]) > 0:
                        raise SilentCorruption(
                            f"decode chunk: {int(flags[-1])} uncorrected "
                            f"corruption(s) detected")
                    return cache, seq, emits, flags
                cache, seq, emits, stats = self._device_call("decode", call)
                self._note_guard(int(stats[-2]))
            else:
                cache, seq, emits, stats = self._device_call(
                    "decode", lambda: self._decode_fn(
                        self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray(pos0), jnp.asarray(self.budgets),
                        jnp.asarray(alive0), n=n))
        except PermanentFault:
            # the chunk never ran (the injector raises before launch):
            # cache/positions are untouched. Shed the affected lanes and
            # free their slots so queued work keeps flowing.
            self._reject_group([self.active[i] for i in live],
                               "device-fault")
            for i in live:
                self._release_slot(i)
            return len(live)
        except SilentCorruption:
            # every retry recomputed the same corrupted chunk; no state
            # was assigned, so the lanes are intact but unservable —
            # finalize them as sdc-uncorrectable and free the slots
            self.guard_events["uncorrectable"] += 1
            self._reject_group([self.active[i] for i in live],
                               "sdc-uncorrectable")
            for i in live:
                self._release_slot(i)
            return len(live)
        self.cache = cache
        seq = np.asarray(seq)                         # the ONE host sync
        emits = np.asarray(emits)
        stats = np.asarray(stats)     # device accumulators, already ready
        t_end = self._clock()
        self._span(f"decode/chunk{n}", "decode", t_start, t_end,
                   steps=n, lanes=len(live), tokens=int(stats[0]),
                   live_end=int(stats[1]))
        self._observe_decode(n, len(live), int(stats[0]), int(stats[1]),
                             t_end - t_start)
        emitted = int(stats[0])
        if emitted > 0 and t_end > t_start:
            self._sec_per_tok.observe((t_end - t_start) / emitted)
            if self._slow_detect is not None:
                # EWMA slow-chunk detection (train/fault.py discipline):
                # a flagged degradation halves the next chunk; a healthy
                # chunk lifts the cap again
                flagged = self._slow_detect.observe(
                    (t_end - t_start) / emitted)
                self._chunk_cap = max(1, n // 2) if flagged else None
        if self.tracer is not None:                   # step-locked replay
            dt_step = (t_end - t_start) / n
            for s in range(n):
                lanes = [i for i in live if emits[s, i]]
                if lanes:
                    self.tracer.on_decode(
                        len(lanes), [int(pos0[i]) + s for i in lanes],
                        t=(t_start - self._t0) + s * dt_step)
        jit_now = self._jit_sizes()
        for i in live:
            r = self.active[i]
            cnt = int(emits[:, i].sum())
            r.out.extend(int(seq[s, i]) for s in range(cnt))
            self.positions[i] += cnt
            self.budgets[i] -= cnt
            hit_eos = (self.eos_id is not None and cnt > 0
                       and int(seq[cnt - 1, i]) == self.eos_id)
            if self.budgets[i] <= 0 or hit_eos:
                if (self.admission.predictor is not None
                        and jit_now == r._jit_epoch):
                    # κ calibration: measured service wall-clock vs the
                    # wave model's prediction for the tokens this request
                    # ACTUALLY produced (len(out), not the full budget —
                    # early-EOS/clamped completions must not bias κ low).
                    # Skipped when the jit cache grew during service: the
                    # wall then includes compile time, which would inflate
                    # κ and shed the requests right behind a cold start.
                    self.admission.observe_service(
                        self.admission.predictor.model_seconds(
                            len(r.prompt), max(1, len(r.out))),
                        t_end - r._admit_t)
                self.admission.finish(r, now=t_end)
                self._release_slot(i)
        # non-finite lanes (flags rode the stats sync): a poisoned lane
        # stopped emitting at the bad step — it cannot have finished above
        # (its budget never reached 0 on a masked emit) — shed it and
        # free the slot; tokens emitted before detection are kept
        poisoned = [(self.active[i], i) for i in live
                    if self.active[i] is not None
                    and stats[2 + i]]
        if poisoned:
            self._shed_non_finite(poisoned, where="decode")
            for _, i in poisoned:
                self._release_slot(i)
        # deadline enforcement at the chunk's existing host sync (zero new
        # syncs): completion above wins over expiry in the same chunk
        for i in self.admission.expired_lanes(self.active, t_end):
            self.admission.expire(self.active[i], "deadline-exceeded")
            self._release_slot(i)
        if self.recycle and self.queue and \
                any(r is None for r in self.active):
            # in-chunk lane recycling: a lane that died inside THIS chunk
            # (eos/budget/deadline/fault — its emit mask went dead at step
            # s < n) hands its slot and pages to queued work at this same
            # host sync. The successor's prefill lands before the next
            # decode chunk, so no idle chunk intervenes, and the tracer
            # records the handoff step-locked (prefill event at this
            # boundary's wall time) exactly like a start-of-step admit.
            occupied = sum(r is not None for r in self.active)
            self._admit()
            self.recycled += max(
                0, sum(r is not None for r in self.active) - occupied)
        self._observe_paged()
        return len(live)

    def _with_table(self, cache):
        """Push the pool's slot-indexed page table into every paged leaf
        (broadcast across stacked layers). An async host->device transfer
        of a tiny int32 array; same pytree structure, so no recompiles."""
        table = self._pool.table()

        def fix(node):
            if isinstance(node, PagedKVCache):
                pt = jnp.asarray(np.broadcast_to(table,
                                                 node.page_table.shape))
                return dataclasses.replace(node, page_table=pt)
            return node
        return jax.tree.map(fix, cache,
                            is_leaf=lambda x: isinstance(x, PagedKVCache))

    def _observe_paged(self) -> None:
        m, pool = self.metrics, self._pool
        if m is None or pool is None:
            return
        m.gauge("serve.paged.occupancy").set(pool.occupancy)
        m.gauge("serve.paged.pages_in_use").set(pool.pages_in_use)
        m.gauge("serve.paged.reserved_pages").set(pool.reserved_pages)
        chunks = m.counter("serve.decode.chunks").value
        if chunks:
            m.gauge("serve.paged.recycle_rate").set(self.recycled / chunks)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        """Drive the engine until queue and slots drain. Raises
        `ServeStalled` (naming the stuck request ids/states) if max_steps
        quanta pass with work still pending — a wedged engine fails loudly
        instead of returning as if it had finished."""
        for _ in range(max_steps):
            if not self.queue and not any(self.active):
                return
            self.step()
        if not self.queue and not any(self.active):
            return
        pending = {r.rid: r.state for r in self.queue}
        pending.update({r.rid: r.state
                        for r in self.active if r is not None})
        raise ServeStalled(pending, max_steps)

    # -- introspection ----------------------------------------------------
    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill shape variants: buckets hit on the bucketed
        path (where the regression gate is <= log2(max_len)), distinct
        prompt lengths on the exact-length fallback (unbounded by
        construction — the quantity the gate exists to expose)."""
        if self.bucketed:
            try:
                return int(self._prefill_fn._cache_size())
            except AttributeError:                    # pragma: no cover
                return len(self._buckets_seen)
        return len(self._buckets_seen)

    @property
    def max_prefill_compiles(self) -> int:
        return max(1, int(math.log2(self.max_len)))

    def paged_kv_stats(self) -> dict:
        """Host-side page-pool accounting (no device sync). KV bytes are
        derived from the paged leaves' actual dtypes/shapes; `dense_bytes`
        is what the same leaves would cost as slots x max_len dense lanes
        — the scaling the paged cache exists to beat. SSM/ring state is
        fixed-size lane-resident (nothing to page) and reported separately
        as `resident_lane_bytes` so the accounting stays honest."""
        pool = self._pool
        if pool is None:
            raise ValueError("paged_kv_stats requires paged=True")
        per_tok = 0
        resident = 0
        is_node = lambda x: isinstance(x, (PagedKVCache, RingKVCache,
                                           SSMCache))
        for leaf in jax.tree.leaves(self.cache, is_leaf=is_node):
            if isinstance(leaf, PagedKVCache):
                per_tok += (leaf.k.nbytes + leaf.v.nbytes) \
                    // (pool.n_pages * pool.page_size)
            elif isinstance(leaf, SSMCache):
                resident += leaf.lane_bytes() * self.slots
            elif isinstance(leaf, RingKVCache):
                resident += leaf.k.nbytes + leaf.v.nbytes
        live_tokens = sum(int(self.positions[i])
                          for i, r in enumerate(self.active)
                          if r is not None)
        return {
            "page_size": pool.page_size,
            "total_pages": pool.n_pages,
            "pages_in_use": pool.pages_in_use,
            "free_pages": pool.free_pages,
            "reserved_pages": pool.reserved_pages,
            "occupancy": pool.occupancy,
            "live_tokens": live_tokens,
            "mapped_tokens": pool.pages_in_use * pool.page_size,
            "kv_bytes_per_token": per_tok,
            "mapped_bytes": pool.pages_in_use * pool.page_size * per_tok,
            "pool_bytes": pool.n_pages * pool.page_size * per_tok,
            "dense_bytes": self.slots * self.max_len * per_tok,
            "resident_lane_bytes": resident,
            "recycled": self.recycled,
        }


def _fix_lengths(cache, true_lens):
    """Reset per-lane cache lengths from the padded bucket length to the
    true prompt lengths, so padded slots stay masked until decode appends
    overwrite them (the bucketed-prefill correctness fixup)."""
    def fix(node):
        if isinstance(node, (KVCache, MLACache)):
            length = jnp.broadcast_to(
                true_lens.astype(node.length.dtype), node.length.shape)
            return dataclasses.replace(node, length=length)
        return node
    return jax.tree.map(
        fix, cache, is_leaf=lambda x: isinstance(x, (KVCache, MLACache)))


_CACHE_NODES = (KVCache, PagedKVCache, RingKVCache, MLACache, SSMCache,
                CrossKV)


def _paged_insert(big_cache, lane_cache, batch_axes, slot_ids, true_lens,
                  dest_pages, slots: int):
    """Merge a dense transient prefill cache into the persistent paged
    cache, node by node: PagedKVCache nodes take the page-granular scatter
    (their dense twin in `lane_cache` reshapes to pages and lands on the
    host-chosen `dest_pages`), every other node — SSM state, ring windows
    — takes the same per-slot dense scatter as the dense impl. The
    node-level tree.map is what lets the two trees disagree in type at
    exactly the paged positions (flatten_up_to pairs whole nodes)."""
    def is_node(x):
        return isinstance(x, _CACHE_NODES)

    def merge(big, lane, ax):
        if isinstance(big, PagedKVCache):
            return big.scatter_prefill(lane, dest_pages, slot_ids,
                                       true_lens)

        def one(b, l, a):
            out = b
            for g in range(slots):                    # static unroll
                valid = slot_ids[g] >= 0
                s = jnp.maximum(slot_ids[g], 0)
                out = jnp.where(
                    valid,
                    jax.lax.dynamic_update_slice_in_dim(
                        out,
                        jax.lax.dynamic_slice_in_dim(l, g, 1, axis=a
                                                     ).astype(b.dtype),
                        s, axis=a),
                    out)
            return out
        return jax.tree.map(one, big, lane, ax)
    return jax.tree.map(merge, big_cache, lane_cache, batch_axes,
                        is_leaf=is_node)


def _write_lane(batched_cache, lane_cache, slot: int):
    """Insert a 1-lane cache into slot `slot` of the batched cache.

    Both trees have identical structure; lane arrays have batch dim 1. The
    batch axis position differs by cache kind: stacked-layer caches are
    [L, B, ...], unstacked [B, ...] — detected from rank difference."""
    def ins(big, small):
        if small.shape == big.shape:
            return small
        # find the axis where big has `slots` and small has 1 (batch axis;
        # includes the per-lane length vectors [B] / [L, B])
        for ax in range(small.ndim):
            if small.shape[ax] == 1 and big.shape[ax] != 1:
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=ax)
        return big
    return jax.tree.map(ins, batched_cache, lane_cache)
