"""Host-side page allocator for the paged KV cache.

The device half lives in ``models.attention.PagedKVCache`` (the pool
arrays + page table the kernels read). This module owns every allocation
decision, and it rides the engine's existing one-host-sync-per-chunk
boundary exactly like PR 7's metrics drain: reserve/map/release all
happen in plain Python at the chunk sync, and the refreshed page table
reaches the device as an ordinary async host->device transfer. Nothing
here reads a device value, so paging adds **zero** host syncs.

Reservation discipline: a request is admitted only if its *worst-case*
page count can be reserved up front — the prompt plus the clamped decode
budget plus one decode chunk of slack (a lane that dies mid-chunk keeps
appending inertly until the sync, so its final chunk can run up to one
chunk past its budget; those writes must land in pages the lane owns,
never drop into another lane's). Because every admitted lane's worst case
is reserved before its prefill, the per-chunk incremental mapping
(``map_to`` covering ``[0, pos + chunk)``) can never fail mid-flight:
page exhaustion is an admission-time event, not a decode-time one.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..train.fault import Ewma


class PageLeak(RuntimeError):
    """A page-pool invariant was violated (double-free, overlap, or pages
    still owned/reserved at a point the caller asserts is drained)."""


class PagePool:
    """Fixed pool of `n_pages` KV pages shared by `slots` serving lanes.

    Page ids are ints in [0, n_pages); the sentinel id ``n_pages`` marks
    an unmapped page-table entry (see PagedKVCache — it must be positive
    so out-of-bounds scatters drop instead of wrapping).
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 max_len: int, chunk_slack: int = 0):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"page_size {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.chunk_slack = int(chunk_slack)
        self.pages_per_lane = max_len // page_size      # P_max
        self._free: list[int] = list(range(self.n_pages - 1, -1, -1))
        self._owned: list[list[int]] = [[] for _ in range(self.slots)]
        self._reserved: list[int] = [0] * self.slots
        self._dirty = True          # device table needs a (re)push
        self.allocated_total = 0
        self.freed_total = 0
        # pages-freed-per-second EWMA, fed by release() timestamps; the
        # slo-aware page-exhaustion shed uses it to estimate how long a
        # queued request would wait for its reservation.
        self._free_rate = Ewma(alpha=0.3)
        self._last_release_t: Optional[float] = None

    # -- introspection -----------------------------------------------------
    @property
    def sentinel(self) -> int:
        return self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved)

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages

    @property
    def dirty(self) -> bool:
        return self._dirty

    def owned(self, slot: int) -> tuple[int, ...]:
        return tuple(self._owned[slot])

    # -- admission ---------------------------------------------------------
    def worst_pages(self, prompt_len: int, budget: int) -> int:
        """Worst-case pages one request can touch: prompt + decode budget
        + one chunk of inert post-death writes, clamped to max_len."""
        tokens = min(self.max_len,
                     int(prompt_len) + int(budget) + self.chunk_slack)
        return -(-max(1, tokens) // self.page_size)

    def can_reserve(self, pages: int) -> bool:
        return self.reserved_pages + pages <= self.n_pages

    def reserve(self, slot: int, pages: int) -> None:
        if self._reserved[slot] or self._owned[slot]:
            raise PageLeak(f"slot {slot} re-reserved while holding "
                           f"{len(self._owned[slot])} pages "
                           f"(reserved={self._reserved[slot]})")
        if not self.can_reserve(pages):
            raise PageLeak(f"reservation overflow: {self.reserved_pages} "
                           f"reserved + {pages} > {self.n_pages}")
        self._reserved[slot] = int(pages)

    # -- mapping -----------------------------------------------------------
    def map_to(self, slot: int, n_tokens: int) -> bool:
        """Map enough pages for `slot` to cover [0, n_tokens). Returns
        True if the device table became stale. Never exceeds the slot's
        reservation — writes past it resolve to the sentinel and drop
        (only inert dead-lane writes can ever reach there)."""
        need = min(-(-int(n_tokens) // self.page_size), self._reserved[slot])
        grew = False
        own = self._owned[slot]
        while len(own) < need:
            if not self._free:      # unreachable under the reserve proof
                raise PageLeak(f"page pool exhausted mapping slot {slot}: "
                               f"reservation discipline violated")
            own.append(self._free.pop())
            self.allocated_total += 1
            grew = True
        if grew:
            self._dirty = True
        return grew

    def release(self, slot: int, now: Optional[float] = None) -> None:
        """Return all of `slot`'s pages to the free list and drop its
        reservation. Safe to call on an empty slot (no-op)."""
        own = self._owned[slot]
        if own:
            freed = len(own)
            self._free.extend(reversed(own))
            self.freed_total += freed
            own.clear()
            self._dirty = True
            if now is not None:
                if (self._last_release_t is not None
                        and now > self._last_release_t):
                    self._free_rate.observe(
                        freed / (now - self._last_release_t))
                self._last_release_t = now
        self._reserved[slot] = 0

    def estimated_wait_s(self, pages: int) -> Optional[float]:
        """Rough seconds until `pages` more pages free up, from the
        release-rate EWMA; None before any rate sample exists."""
        rate = self._free_rate.value
        if rate is None or rate <= 0:
            return None
        return pages / rate

    # -- device table ------------------------------------------------------
    def table(self) -> np.ndarray:
        """Slot-indexed page table [slots, P_max] int32, sentinel-padded.
        Marks the pool clean: the caller is pushing this to the device."""
        t = np.full((self.slots, self.pages_per_lane), self.sentinel,
                    np.int32)
        for s, own in enumerate(self._owned):
            if own:
                t[s, :len(own)] = own
        self._dirty = False
        return t

    # -- invariants --------------------------------------------------------
    def check(self) -> None:
        """Raise PageLeak unless {free} + {owned} exactly partition the
        pool and no reservation is overdrawn."""
        seen: set[int] = set(self._free)
        if len(seen) != len(self._free):
            raise PageLeak("duplicate page id on the free list")
        for s, own in enumerate(self._owned):
            if len(own) > self._reserved[s]:
                raise PageLeak(f"slot {s} owns {len(own)} pages over its "
                               f"reservation {self._reserved[s]}")
            for p in own:
                if p in seen:
                    raise PageLeak(f"page {p} owned by slot {s} is also "
                                   f"free or owned elsewhere")
                seen.add(p)
        if seen != set(range(self.n_pages)):
            raise PageLeak(f"page partition broken: {len(seen)} of "
                           f"{self.n_pages} pages accounted for")

    def assert_drained(self) -> None:
        self.check()
        if self.pages_in_use or self.reserved_pages:
            raise PageLeak(f"pool not drained: {self.pages_in_use} pages "
                           f"in use, {self.reserved_pages} reserved")
        if self.allocated_total != self.freed_total:
            raise PageLeak(f"alloc/free imbalance: {self.allocated_total} "
                           f"allocated vs {self.freed_total} freed")
