"""Reference serving engine: the seed per-step hot loop, kept as the
scalar oracle for the optimized engine in serve/engine.py.

One eager prefill per request (recompiling/redispatching for every distinct
prompt length) and one host round-trip per decoded token — exactly the
behavior benchmarks/serving.py quantifies the bucketed + fused engine
against. Output semantics are the contract both engines share:
`Request.out` holds max_new_tokens greedy tokens (first from prefill),
truncated at eos_id inclusive.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from .engine import Request, _write_lane


class ReferenceEngine:
    """Seed ServeEngine: step-locked continuous batching, host-synced per
    token. `jit_prefill=True` jits the prefill call (used by the serving
    benchmark so compile counts are observable via `_cache_size`)."""

    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 512, src_len: int = 0,
                 eos_id: Optional[int] = None, tracer=None,
                 jit_prefill: bool = False):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.src_len = src_len
        self.eos_id = eos_id
        self.tracer = tracer
        self.cache = model.init_cache(slots, max_len, src_len=src_len)
        self.active: list[Optional[Request]] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.budgets = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill) if jit_prefill \
            else model.prefill

    # -- request flow --------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request) -> None:
        """Prefill a single request into one slot lane of the batched
        cache. The lane cache is built with the engine's src_len so
        encoder-decoder cross-KV lanes line up with the batched cache."""
        S = len(req.prompt)
        if self.tracer is not None:
            self.tracer.on_prefill(req.rid, S)
        lane_cache = self.model.init_cache(1, self.max_len,
                                           src_len=self.src_len)
        batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
        for key, val in req.extras.items():
            batch[key] = jnp.asarray(val)
        logits, lane_cache = self._prefill(self.params, batch, lane_cache)
        self.cache = _write_lane(self.cache, lane_cache, slot)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.active[slot] = req
        self.positions[slot] = S
        # clamp so the lane never appends past max_len (oversized requests
        # degrade to shorter completions, matching serve/engine.py); a
        # prompt that fills the cache retires with just the prefill token
        self.budgets[slot] = min(req.max_new_tokens - 1,
                                 max(0, self.max_len - S))
        if S >= self.max_len:
            req.done = True
            self.active[slot] = None

    # -- decode loop -----------------------------------------------------
    def step(self) -> int:
        """One step-locked decode over all active slots. Returns #active."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        if self.tracer is not None:
            self.tracer.on_decode(len(live),
                                  [int(self.positions[i]) for i in live])
        toks = np.zeros(self.slots, np.int32)
        for i in live:
            toks[i] = self.active[i].out[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.positions))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in live:
            r = self.active[i]
            tok = int(nxt[i])
            r.out.append(tok)
            self.positions[i] += 1
            self.budgets[i] -= 1
            if self.budgets[i] <= 0 or (self.eos_id is not None
                                        and tok == self.eos_id):
                r.done = True
                self.active[i] = None
        return len(live)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not any(self.active):
                return
            self.step()
