"""Reference serving engine: the seed per-step hot loop, kept as the
scalar oracle for the optimized engine in serve/engine.py.

One eager prefill per request (recompiling/redispatching for every distinct
prompt length) and one host round-trip per decoded token — exactly the
behavior benchmarks/serving.py quantifies the bucketed + fused engine
against. Output semantics are the contract both engines share:
`Request.out` holds max_new_tokens greedy tokens (first from prefill),
truncated at eos_id inclusive.

The oracle speaks the same admission protocol as the optimized engine
(serve/admission.py): validation at submit, the same queue sweep /
ordering / shedding decisions, and terminal states — but checks deadlines
per token (it syncs every step anyway), making it the *semantic* oracle
for the chunk-boundary checks in ServeEngine: any request BOTH engines
complete must carry identical tokens; the oracle never runs chaos.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from .admission import (AdmissionConfig, AdmissionController, SLO_AWARE,
                        ServeStalled, WaveLatencyPredictor)
from .chaos import NumericalFault, check_lanes_finite
from .engine import Request, _write_lane


class ReferenceEngine:
    """Seed ServeEngine: step-locked continuous batching, host-synced per
    token. `jit_prefill=True` jits the prefill call (used by the serving
    benchmark so compile counts are observable via `_cache_size`)."""

    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 512, src_len: int = 0,
                 eos_id: Optional[int] = None, tracer=None,
                 jit_prefill: bool = False, admission=None, clock=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.src_len = src_len
        self.eos_id = eos_id
        self.tracer = tracer
        self.cache = model.init_cache(slots, max_len, src_len=src_len)
        self.active: list[Optional[Request]] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.budgets = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill) if jit_prefill \
            else model.prefill
        self._clock = clock if clock is not None else time.perf_counter
        if admission is None:
            admission = AdmissionConfig()
        elif isinstance(admission, str):
            admission = AdmissionConfig(policy=admission)
        if isinstance(admission, AdmissionConfig):
            predictor = WaveLatencyPredictor(
                model.cfg, admission.design, admission.tdp,
                faulty_pods=admission.faulty_pods) \
                if admission.policy == SLO_AWARE else None
            admission = AdmissionController(
                admission, slots=slots, max_len=max_len,
                predictor=predictor)
        self.admission: AdmissionController = admission
        self.guard_events = {"non_finite": 0}

    # -- request flow --------------------------------------------------
    def submit(self, req: Request) -> None:
        if self.admission.on_submit(self.queue, req, self._clock()):
            self.queue.append(req)

    def _shed_non_finite(self, pairs: list, where: str) -> None:
        """Finalize lanes whose logits went NaN/Inf: the typed
        NumericalFault is raised (check_lanes_finite) and caught here —
        the forward pass is deterministic so there is no retry; each
        affected request ends ``rejected`` with terminal reason
        ``non-finite-logits`` (same contract as ServeEngine)."""
        try:
            check_lanes_finite([(lane, True) for _, lane in pairs], where)
        except NumericalFault:
            for r, _ in pairs:
                self.admission.reject(r, "non-finite-logits")
            self.guard_events["non_finite"] += len(pairs)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self) -> None:
        self.admission.sweep(self.queue, self._clock())
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request) -> None:
        """Prefill a single request into one slot lane of the batched
        cache. The lane cache is built with the engine's src_len so
        encoder-decoder cross-KV lanes line up with the batched cache."""
        S = len(req.prompt)
        if self.tracer is not None:
            self.tracer.on_prefill(req.rid, S)
        lane_cache = self.model.init_cache(1, self.max_len,
                                           src_len=self.src_len)
        batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
        for key, val in req.extras.items():
            batch[key] = jnp.asarray(val)
        logits, lane_cache = self._prefill(self.params, batch, lane_cache)
        # non-finite guard: a poisoned prefill never activates the slot —
        # recompute would return the same NaN/Inf, so the lane is rejected
        # (the oracle syncs per request anyway, the extra check is free)
        if not bool(jnp.isfinite(logits[0]).all()):
            self._shed_non_finite([(req, slot)], where="prefill")
            return
        self.cache = _write_lane(self.cache, lane_cache, slot)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.active[slot] = req
        self.positions[slot] = S
        # clamp so the lane never appends past max_len (oversized requests
        # degrade to shorter completions, matching serve/engine.py); a
        # prompt that fills the cache retires with just the prefill token
        now = self._clock()
        self.budgets[slot] = self.admission.clamp_budget(
            req, min(req.max_new_tokens - 1, max(0, self.max_len - S)),
            len(self.queue))
        self.admission.note_admitted(req, now)
        if S >= self.max_len:
            self.admission.finish(req, now=now)
            self.active[slot] = None

    # -- decode loop -----------------------------------------------------
    def step(self) -> int:
        """One step-locked decode over all active slots. Returns #active."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        if self.tracer is not None:
            self.tracer.on_decode(len(live),
                                  [int(self.positions[i]) for i in live])
        toks = np.zeros(self.slots, np.int32)
        for i in live:
            toks[i] = self.active[i].out[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.positions))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        fin = np.asarray(jnp.isfinite(logits).all(axis=-1))
        now = self._clock()
        poisoned = [(self.active[i], i) for i in live if not fin[i]]
        if poisoned:
            self._shed_non_finite(poisoned, where="decode")
            for _, i in poisoned:
                self.active[i] = None
        for i in live:
            r = self.active[i]
            if r is None:        # lane shed above: no token appended
                continue
            tok = int(nxt[i])
            r.out.append(tok)
            self.positions[i] += 1
            self.budgets[i] -= 1
            if self.budgets[i] <= 0 or (self.eos_id is not None
                                        and tok == self.eos_id):
                self.admission.finish(r, now=now)
                self.active[i] = None
        # per-token deadline enforcement (the oracle syncs every step, so
        # this is the tightest check the chunked engine approximates)
        for i in self.admission.expired_lanes(self.active, now):
            self.admission.expire(self.active[i], "deadline-exceeded")
            self.active[i] = None
        return len(live)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        """Drain the engine; raises ServeStalled naming the stuck requests
        when max_steps quanta pass with work still pending (same contract
        as ServeEngine.run_to_completion)."""
        for _ in range(max_steps):
            if not self.queue and not any(self.active):
                return
            self.step()
        if not self.queue and not any(self.active):
            return
        pending = {r.rid: r.state for r in self.queue}
        pending.update({r.rid: r.state
                        for r in self.active if r is not None})
        raise ServeStalled(pending, max_steps)
