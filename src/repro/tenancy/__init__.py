"""repro.tenancy — multi-tenant co-scheduling on the batched DSE engine.

SOSA's third pillar (§6.1, Fig 11): recover idle pod slices by
co-scheduling independent inference streams. This package turns the
one-off scalar loop that used to live in benchmarks/multitenancy.py into a
subsystem:

  mix.py     — declarative tenant mixes; merged co-schedules packed so a
               (designs x mixes) grid is ONE core.simulator.analyze_batch
  planner.py — time-multiplexed vs space-shared co-schedule planner with
               per-tenant latency / SLO attainment / fairness / effective
               TOPS, validated against the slice-accurate SliceScheduler
  sweep.py   — the batched Fig-11 reproduction + tenant-mix DSE
  trace.py   — bridge from serve/engine.py request streams to planner
               tenants (ServeEngine(tracer=ServeTraceRecorder()))
"""

from .mix import (Tenant, TenantMix, mix_grid, pack_mixes, solo_workloads,
                  tenant, tenant_depths)
from .planner import (SPACE_SHARE, TIME_MUX, TenancyPlan, TenantReport,
                      partition_pods, plan_mix_scalar, plan_mixes,
                      plan_space_share, plan_time_mux)
from .sweep import (default_mixes, dse_designs, fig11_mixes, fig11_sweep,
                    mix_dse)
from .trace import ServeTraceRecorder, trace_tenant, trace_to_gemms

__all__ = [
    "Tenant", "TenantMix", "mix_grid", "pack_mixes", "solo_workloads",
    "tenant", "tenant_depths",
    "SPACE_SHARE", "TIME_MUX", "TenancyPlan", "TenantReport",
    "partition_pods", "plan_mix_scalar", "plan_mixes", "plan_space_share",
    "plan_time_mux",
    "default_mixes", "dse_designs", "fig11_mixes", "fig11_sweep", "mix_dse",
    "ServeTraceRecorder", "trace_tenant", "trace_to_gemms",
]
