"""Declarative tenant-mix construction (SOSA §6.1 multi-tenancy).

A `Tenant` is one inference stream: a GEMM trace (anything from
core/workloads.py, or a serving trace recorded off serve/engine.py via
tenancy/trace.py), replicated `replicas` times, optionally carrying a
latency SLO. A `TenantMix` is a set of tenants co-scheduled on one
accelerator; `TenantMix.merged()` re-bases the streams' GEMM ids with
`core.simulator.merge_workloads` so they stay dependency-disjoint and
interleave freely — the source of the paper's Fig-11 gain.

`mix_grid` builds a whole design-space axis of mixes (workload suite x
batch x replicas x SLO), and `pack_mixes` packs their merged co-schedules
into one `PackedWorkloads`, so an entire (designs x tenant-mixes) grid is
ONE `analyze_batch` call (see tenancy/planner.py; the scalar
`merge_workloads` + `analyze` path stays as the oracle in
tests/test_tenancy.py and benchmarks/multitenancy.py).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable

import numpy as np

from ..core.simulator import PackedWorkloads, merge_workloads, pack_workloads
from ..core.tiling import GemmSpec, gemm_levels


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One co-scheduled inference stream (workload + QoS envelope)."""

    name: str
    gemms: tuple[GemmSpec, ...]
    replicas: int = 1                      # identical streams co-scheduled
    slo_latency_s: float | None = None     # per-inference latency target

    def __post_init__(self):
        if not self.gemms:
            raise ValueError(f"tenant {self.name!r} has an empty trace")
        if self.replicas < 1:
            raise ValueError(f"tenant {self.name!r}: replicas must be >= 1")

    @property
    def macs(self) -> int:
        """Total MACs of all replica streams (space-share partition weight)."""
        return self.replicas * sum(g.macs for g in self.gemms)

    @property
    def depth(self) -> int:
        """Topological depth of one stream (levels occupied in a merge —
        disjoint streams all start at level 0, see gemm_levels)."""
        return int(gemm_levels(list(self.gemms)).max()) + 1

    def streams(self) -> list[list[GemmSpec]]:
        return [list(self.gemms) for _ in range(self.replicas)]


@dataclasses.dataclass(frozen=True)
class TenantMix:
    """A named set of tenants sharing one accelerator."""

    name: str
    tenants: tuple[Tenant, ...]

    def __post_init__(self):
        if not self.tenants:
            raise ValueError(f"mix {self.name!r} has no tenants")

    @property
    def num_streams(self) -> int:
        return sum(t.replicas for t in self.tenants)

    @property
    def total_macs(self) -> int:
        return sum(t.macs for t in self.tenants)

    def merged(self) -> list[GemmSpec]:
        """The co-schedule: all replica streams merged dependency-disjoint."""
        streams: list[list[GemmSpec]] = []
        for t in self.tenants:
            streams.extend(t.streams())
        return merge_workloads(*streams)


def tenant(name: str, gemms: Iterable[GemmSpec], replicas: int = 1,
           slo_latency_s: float | None = None) -> Tenant:
    """Convenience constructor accepting any GemmSpec iterable."""
    return Tenant(name=name, gemms=tuple(gemms), replicas=replicas,
                  slo_latency_s=slo_latency_s)


def mix_grid(
    factories: dict[str, Callable[[int], list[GemmSpec]]],
    batches: tuple[int, ...] = (1,),
    replicas: tuple[int, ...] = (1,),
    pair_size: int = 2,
    slo_latency_s: float | None = None,
) -> list[TenantMix]:
    """The tenant-mix design-space axis: every `pair_size`-combination of
    the named workloads, at every batch and replica count.

    `factories` maps workload name -> (batch -> GemmSpec list), e.g.
    ``{"resnet50": lambda b: resnet(50, 224, batch=b), ...}``. All tenants
    of a mix share the batch/replica/SLO setting — per-tenant asymmetry is
    expressed by constructing TenantMix directly.
    """
    names = sorted(factories)
    if pair_size > len(names):
        raise ValueError(f"pair_size {pair_size} > {len(names)} workloads")
    mixes: list[TenantMix] = []
    for combo in itertools.combinations(names, pair_size):
        for b in batches:
            for r in replicas:
                # tenant names carry the batch — a tenant name must denote
                # ONE trace across all mixes (solo_workloads relies on it)
                ts = tuple(
                    Tenant(name=f"{n}@b{b}", gemms=tuple(factories[n](b)),
                           replicas=r, slo_latency_s=slo_latency_s)
                    for n in combo
                )
                tag = "+".join(combo)
                mixes.append(TenantMix(name=f"{tag}@b{b}x{r}", tenants=ts))
    return mixes


def pack_mixes(mixes: list[TenantMix]) -> PackedWorkloads:
    """Merged co-schedules of all mixes as one PackedWorkloads — the
    tenant-mix axis of the batched (designs x mixes) grid."""
    seen: set[str] = set()
    for m in mixes:
        if m.name in seen:
            raise ValueError(f"duplicate mix name {m.name!r}")
        seen.add(m.name)
    return pack_workloads({m.name: m.merged() for m in mixes})


def solo_workloads(mixes: list[TenantMix]) -> dict[str, list[GemmSpec]]:
    """Each distinct tenant's single-stream trace, keyed by tenant name —
    the solo baselines the planner needs for slowdown / sequential
    comparisons (packed alongside the mixes, still one analyze_batch)."""
    out: dict[str, list[GemmSpec]] = {}
    for m in mixes:
        for t in m.tenants:
            if t.name not in out:
                out[t.name] = list(t.gemms)
            else:
                prev = out[t.name]
                if len(prev) != len(t.gemms) or any(
                        (a.d1, a.d2, a.d3, a.gemm_id, a.depends_on)
                        != (b.d1, b.d2, b.d3, b.gemm_id, b.depends_on)
                        for a, b in zip(prev, t.gemms)):
                    raise ValueError(
                        f"tenant name {t.name!r} reused with a different "
                        "trace across mixes")
    return out


def tenant_depths(mix: TenantMix) -> np.ndarray:
    """(num_streams,) merged-trace completion level per replica stream, in
    merge order. Disjoint streams each start at level 0 of the merged
    co-schedule, so a stream completes when its own deepest level drains."""
    return np.array(
        [t.depth for t in mix.tenants for _ in range(t.replicas)],
        dtype=np.int64)
