"""Multi-tenant co-schedule planner (SOSA §6.1, Fig 11).

Two co-scheduling policies over a (designs x tenant-mixes) grid:

  * time-multiplexed ("time-mux") — all pods are shared: the mix's merged
    co-schedule (mix.TenantMix.merged) runs as one workload, idle pod
    slices of one tenant's waves absorbing the other tenants' tiles. The
    whole grid — every mix's merged trace plus every tenant's solo
    baseline — is ONE `analyze_batch` call over `pack_mixes` +
    `solo_workloads`. A stream's latency is the drain time of its own
    deepest level inside the merged schedule (`BatchedAnalysis.
    level_slices` cumulated to the stream's depth).

  * space-shared ("space-share") — pods are partitioned: each stream gets
    a power-of-two pod share proportional to its MACs and runs alone on it
    (an isolated sub-accelerator, same array/fabric). All (design, mix,
    stream) partitions are evaluated in one `analyze_batch` over an
    expanded DesignVector.

Every plan reports per-tenant latency / SLO attainment, Jain fairness over
per-stream progress shares, effective TOPS @TDP, and the sequential
(back-to-back solo) baseline — `parallel_gain` is the paper's Fig-11
metric (1.44x for ResNet+BERT on 256 pods).

Validation: `plan_mix_scalar` is the pure-Python `merge_workloads` +
wave-model oracle (analyze_scalar's math, cumulated per level) the
batched path must match exactly, and the
time-mux makespan is checked against the slice-accurate `SliceScheduler`
(core/scheduler.py) on merged graphs inside the calibrated parity bands —
both in tests/test_tenancy.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.arrays import AcceleratorConfig
from ..core.dse import Design, build_accel, build_design_vector
from ..core.simulator import (_levels, _slice_cycles, analyze_batch,
                              icn_efficiency, pack_workloads)
from ..core.tiling import tile_counts
from .mix import TenantMix, solo_workloads, tenant_depths

TIME_MUX = "time-mux"
SPACE_SHARE = "space-share"


@dataclasses.dataclass(frozen=True)
class TenantReport:
    """One replica stream's outcome inside a co-schedule."""

    tenant: str
    stream: int                    # replica index within the mix
    latency_s: float               # completion time inside the co-schedule
    solo_latency_s: float          # alone on the full machine
    slo_latency_s: float | None
    pods: int                      # pods visible to this stream

    @property
    def slowdown(self) -> float:
        """Co-scheduled latency over solo latency (>= 1 under sharing)."""
        return self.latency_s / self.solo_latency_s if self.solo_latency_s \
            else float("inf")

    @property
    def slo_met(self) -> bool | None:
        if self.slo_latency_s is None:
            return None
        return self.latency_s <= self.slo_latency_s


@dataclasses.dataclass(frozen=True)
class TenancyPlan:
    """A (design, mix, policy) cell of the co-scheduling grid."""

    mix: str
    policy: str
    rows: int
    cols: int
    num_pods: int
    interconnect: str
    makespan_s: float
    utilization: float
    effective_tops_at_tdp: float
    sequential_effective_tops: float   # back-to-back solo baseline
    streams: tuple[TenantReport, ...]

    @property
    def parallel_gain(self) -> float:
        """Fig-11 headline: co-scheduled over sequential effective TOPS."""
        if self.sequential_effective_tops == 0:
            return float("inf")
        return self.effective_tops_at_tdp / self.sequential_effective_tops

    @property
    def slo_attainment(self) -> float:
        """Fraction of streams meeting their SLO (1.0 when none declared)."""
        declared = [s for s in self.streams if s.slo_latency_s is not None]
        if not declared:
            return 1.0
        return sum(1 for s in declared if s.slo_met) / len(declared)

    @property
    def fairness(self) -> float:
        """Jain's index over per-stream progress shares (solo/latency):
        1.0 when sharing slows every stream equally."""
        x = np.array([s.solo_latency_s / s.latency_s for s in self.streams])
        if not len(x) or not x.sum():
            return 0.0
        return float(x.sum() ** 2 / (len(x) * (x ** 2).sum()))


def partition_pods(num_pods: int, macs: np.ndarray) -> np.ndarray:
    """Power-of-two pod shares proportional to per-stream MACs.

    Every stream gets at least one pod; shares are floored to powers of two
    (pod groups stay butterfly-alignable) and the largest share is halved
    until the partition fits. Raises when there are more streams than pods
    (time-mux is the right policy there).
    """
    macs = np.asarray(macs, dtype=np.float64)
    if len(macs) > num_pods:
        raise ValueError(
            f"{len(macs)} streams > {num_pods} pods: space-sharing cannot "
            "give every stream a pod; use the time-mux policy")
    shares = np.maximum(1.0, macs / macs.sum() * num_pods)
    pods = 2 ** np.floor(np.log2(shares)).astype(np.int64)
    while pods.sum() > num_pods:
        i = int(np.argmax(pods))
        pods[i] //= 2
    return pods


# ---------------------------------------------------------------------------
# batched planner
# ---------------------------------------------------------------------------


def _stream_names(mix: TenantMix) -> list[tuple[str, int]]:
    return [(t.name, i) for t in mix.tenants for i in range(t.replicas)]


def _stream_slos(mix: TenantMix) -> list[float | None]:
    return [t.slo_latency_s for t in mix.tenants for _ in range(t.replicas)]


def plan_time_mux(
    mixes: list[TenantMix],
    designs: list[Design],
    tdp: float = 400.0,
) -> list[list[TenancyPlan]]:
    """The batched time-multiplexed planner: one `analyze_batch` call for
    the whole (designs x mixes) grid, merged co-schedules and solo
    baselines packed side by side. Returns plans indexed [design][mix]."""
    solos = solo_workloads(mixes)
    solo_names = sorted(solos)
    n_mix = len(mixes)
    # one packed suite: mixes first, then the distinct solo traces
    suite = {m.name: m.merged() for m in mixes}
    suite.update({f"solo/{n}": solos[n] for n in solo_names})
    packed = pack_workloads(suite)
    dv = build_design_vector(designs, tdp)
    batch = analyze_batch(packed, dv)

    solo_col = {n: n_mix + i for i, n in enumerate(solo_names)}
    clock = dv.clock_hz
    seg_starts = packed.wl_seg_starts
    # per-mix stream bookkeeping is design-invariant — hoist it
    mix_streams = [list(zip(_stream_names(mix), tenant_depths(mix),
                            _stream_slos(mix))) for mix in mixes]

    out: list[list[TenancyPlan]] = []
    for p in range(dv.num_points):
        row: list[TenancyPlan] = []
        pods = int(dv.num_pods[p])
        pe = int(dv.rows[p] * dv.cols[p])
        peak_tops = float(batch.peak_tops_at_tdp[p])
        for m, mix in enumerate(mixes):
            s0 = int(seg_starts[m])
            slice_cyc = float(batch.cycles_per_tile[p, m])
            lvl = batch.level_slices[p]
            reports = []
            for (tname, si), depth, slo in mix_streams[m]:
                lat_cyc = float(lvl[s0:s0 + depth].sum()) * slice_cyc
                solo_cyc = float(batch.total_cycles[p, solo_col[tname]])
                reports.append(TenantReport(
                    tenant=tname, stream=si,
                    latency_s=lat_cyc / clock,
                    solo_latency_s=solo_cyc / clock,
                    slo_latency_s=slo, pods=pods))
            seq_cycles = sum(
                float(batch.total_cycles[p, solo_col[t]])
                for (t, _), _, _ in mix_streams[m])
            total_macs = float(batch.total_macs[m])
            util_seq = total_macs / (pods * pe * seq_cycles) \
                if seq_cycles else 0.0
            row.append(TenancyPlan(
                mix=mix.name, policy=TIME_MUX,
                rows=int(dv.rows[p]), cols=int(dv.cols[p]), num_pods=pods,
                interconnect=designs[p][2],
                makespan_s=float(batch.total_cycles[p, m]) / clock,
                utilization=float(batch.utilization[p, m]),
                effective_tops_at_tdp=float(
                    batch.effective_tops_at_tdp[p, m]),
                sequential_effective_tops=peak_tops * util_seq,
                streams=tuple(reports)))
        out.append(row)
    return out


def plan_space_share(
    mixes: list[TenantMix],
    designs: list[Design],
    tdp: float = 400.0,
) -> list[list[TenancyPlan]]:
    """The batched space-shared planner: every (design, mix, stream)
    partition plus every full-machine solo baseline evaluated in one
    `analyze_batch` over an expanded DesignVector. Returns [design][mix]."""
    solos = solo_workloads(mixes)
    solo_names = sorted(solos)
    solo_col = {n: i for i, n in enumerate(solo_names)}
    packed = pack_workloads({n: solos[n] for n in solo_names})

    base = build_design_vector(designs, tdp)   # pod counts may be isopower
    # per-mix stream bookkeeping is design-invariant — hoist it
    mix_streams = [list(zip(_stream_names(mix), _stream_slos(mix)))
                   for mix in mixes]
    mix_macs = [np.array([t.macs / t.replicas
                          for t in mix.tenants
                          for _ in range(t.replicas)], dtype=np.float64)
                for mix in mixes]
    rows_ex: list[Design] = []
    cell: dict[tuple[int, int, int], int] = {}  # (p, m, stream) -> row
    parts: dict[tuple[int, int], np.ndarray] = {}
    for p, d in enumerate(designs):
        pods_full = int(base.num_pods[p])
        for m, mix in enumerate(mixes):
            pods_t = partition_pods(pods_full, mix_macs[m])
            parts[(p, m)] = pods_t
            for s, np_t in enumerate(pods_t):
                cell[(p, m, s)] = len(rows_ex)
                rows_ex.append((d[0], d[1], d[2], int(np_t)))
    full_row0 = len(rows_ex)
    rows_ex.extend((d[0], d[1], d[2], int(base.num_pods[p]))
                   for p, d in enumerate(designs))

    dv = build_design_vector(rows_ex, tdp)
    batch = analyze_batch(packed, dv)
    clock = dv.clock_hz

    out: list[list[TenancyPlan]] = []
    for p, d in enumerate(designs):
        row: list[TenancyPlan] = []
        pods_full = int(base.num_pods[p])
        pe = int(base.rows[p] * base.cols[p])
        fp = full_row0 + p
        peak_tops = float(batch.peak_tops_at_tdp[fp])
        for m, mix in enumerate(mixes):
            pods_t = parts[(p, m)]
            reports = []
            lat_cycles = []
            for s, ((tname, si), slo) in enumerate(mix_streams[m]):
                r_ = cell[(p, m, s)]
                w = solo_col[tname]
                lat = float(batch.total_cycles[r_, w])
                solo_cyc = float(batch.total_cycles[fp, w])
                lat_cycles.append(lat)
                reports.append(TenantReport(
                    tenant=tname, stream=si, latency_s=lat / clock,
                    solo_latency_s=solo_cyc / clock,
                    slo_latency_s=slo, pods=int(pods_t[s])))
            makespan = max(lat_cycles)
            total_macs = float(mix.total_macs)
            util = total_macs / (pods_full * pe * makespan)
            seq_cycles = sum(float(batch.total_cycles[fp, solo_col[t]])
                             for (t, _), _ in mix_streams[m])
            util_seq = total_macs / (pods_full * pe * seq_cycles)
            row.append(TenancyPlan(
                mix=mix.name, policy=SPACE_SHARE,
                rows=d[0], cols=d[1], num_pods=pods_full,
                interconnect=d[2],
                makespan_s=makespan / clock,
                utilization=util,
                effective_tops_at_tdp=peak_tops * util,
                sequential_effective_tops=peak_tops * util_seq,
                streams=tuple(reports)))
        out.append(row)
    return out


def plan_mixes(
    mixes: list[TenantMix],
    designs: list[Design],
    policy: str = TIME_MUX,
    tdp: float = 400.0,
) -> list[list[TenancyPlan]]:
    """Plan every (design, mix) cell under one policy; [design][mix]."""
    if policy == TIME_MUX:
        return plan_time_mux(mixes, designs, tdp)
    if policy == SPACE_SHARE:
        return plan_space_share(mixes, designs, tdp)
    raise ValueError(f"unknown policy {policy!r}")


# ---------------------------------------------------------------------------
# scalar oracle (pure-Python merge_workloads + analyze_scalar)
# ---------------------------------------------------------------------------


def _wave_levels(gemms, accel: AcceleratorConfig,
                 interconnect: str,
                 faulty_pods: int = 0) -> tuple[list[float], float]:
    """(per-level wave counts, service cycles per slice) of the analytical
    model — analyze_scalar's inner loop, exposed so the oracle can cumulate
    per-stream completion and un-truncated float totals (the batched path
    keeps cycles as floats; SimResult.total_cycles is int-truncated).

    faulty_pods shrinks the wave width only (survivor count); the fabric
    spec stays full-machine, so latency is monotone in masked pods."""
    arr = accel.array
    r, c = arr.rows, arr.cols
    eff_pods = (accel.num_pods - faulty_pods) * icn_efficiency(interconnect)

    level_slices: list[float] = []
    total_tiles = 0
    k_sum = 0.0
    for level in _levels(gemms):
        pod_slices = 0.0
        crit = 0.0
        for g in level:
            n_i, n_j, n_l = tile_counts(g.d1, g.d2, g.d3, r, c, None)
            pod_slices += n_i * n_j * n_l
            crit = max(crit, n_j)
            total_tiles += n_i * n_j * n_l
            k_sum += n_i * n_j * n_l * (g.d1 / n_i)
        level_slices.append(max(crit, pod_slices / eff_pods))
    k_bar = (k_sum / total_tiles) if total_tiles else r
    return level_slices, _slice_cycles(accel, interconnect, k_bar)


def _scalar_float_cycles(gemms, accel: AcceleratorConfig,
                         interconnect: str, faulty_pods: int = 0) -> float:
    """Un-truncated total cycles of the wave model (matches the batched
    engine's float total_cycles to rounding error)."""
    level_slices, slice_cyc = _wave_levels(gemms, accel, interconnect,
                                           faulty_pods=faulty_pods)
    return sum(level_slices) * slice_cyc


def predict_latency_s(gemms, design: Design, tdp: float = 400.0,
                      faulty_pods: int = 0) -> float:
    """Wave-model service latency (seconds) of one GEMM stream on one
    design point — the per-request *prediction hook* the serving admission
    controller uses (serve/admission.py). Same math as a TenantReport's
    `latency_s` for a solo stream: un-truncated float cycles of the
    analytical wave model over the stream's levels, divided by the design
    clock. The admission controller feeds it `tenancy.trace.request_gemms`
    streams, so `slo_attainment`'s met/missed accounting finally drives
    admit/shed decisions instead of only reporting them.

    ``faulty_pods`` prices the stream on the degraded array (that many
    pods masked out of the wave width, core/simulator `faulty_pods`
    semantics; the fabric spec and isopower normalization keep
    full-machine geometry): latency rises monotonically as capacity
    falls, so the slo-aware admission policy sheds load proportionally
    to the lost pods."""
    rows, cols, icn, pods = design
    if not 0 <= int(faulty_pods) < pods:
        raise ValueError(f"faulty_pods must be in [0, {pods}), "
                         f"got {faulty_pods}")
    accel = build_accel(rows, cols, icn, tdp, pods)
    return _scalar_float_cycles(list(gemms), accel, icn,
                                faulty_pods=int(faulty_pods)) / \
        accel.array.clock_hz


def plan_mix_scalar(
    mix: TenantMix,
    design: Design,
    tdp: float = 400.0,
) -> TenancyPlan:
    """Time-mux plan for one (design, mix) cell through the scalar path —
    the independent merge_workloads + wave-model oracle the batched
    planner is tested against. Every field derives from ONE per-level
    pass over the merged trace (plus one per solo baseline), so the plan
    is internally consistent by construction."""
    rows, cols, icn, pods = design
    accel = build_accel(rows, cols, icn, tdp, pods)
    clock = accel.array.clock_hz
    merged_gemms = mix.merged()
    level_slices, slice_cyc = _wave_levels(merged_gemms, accel, icn)
    makespan_cycles = sum(level_slices) * slice_cyc
    total_macs = sum(g.macs for g in merged_gemms)
    num_pe = accel.num_pods * accel.array.num_pe
    util = total_macs / (num_pe * makespan_cycles)

    solo_cycles = {t.name: _scalar_float_cycles(list(t.gemms), accel, icn)
                   for t in mix.tenants}
    reports = []
    for (tname, si), slo, depth in zip(_stream_names(mix),
                                       _stream_slos(mix),
                                       tenant_depths(mix)):
        lat = sum(level_slices[:depth]) * slice_cyc
        reports.append(TenantReport(
            tenant=tname, stream=si, latency_s=lat / clock,
            solo_latency_s=solo_cycles[tname] / clock,
            slo_latency_s=slo, pods=accel.num_pods))
    seq_cycles = sum(solo_cycles[t] for t, _ in _stream_names(mix))
    util_seq = total_macs / (num_pe * seq_cycles)
    return TenancyPlan(
        mix=mix.name, policy=TIME_MUX,
        rows=rows, cols=cols, num_pods=accel.num_pods, interconnect=icn,
        makespan_s=makespan_cycles / clock,
        utilization=util,
        effective_tops_at_tdp=accel.peak_ops_at_tdp * util / 1e12,
        sequential_effective_tops=accel.peak_ops_at_tdp * util_seq / 1e12,
        streams=tuple(reports))
