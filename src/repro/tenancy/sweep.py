"""Batched multi-tenancy sweeps (SOSA Fig 11 / §6.1 + tenant-mix DSE).

Two entry points, both riding the batched planner (tenancy/planner.py —
one `analyze_batch` call per policy over the whole grid):

  * `fig11_sweep` — the paper's co-scheduling experiment: ResNet + BERT
    merged vs back-to-back sequential across batch sizes and pod counts.
    The paper reports a 1.44x parallel-over-sequential gain on 256 pods
    (Fig 11); `TenancyPlan.parallel_gain` is that metric per cell.

  * `mix_dse` — tenant mixes as first-class design-space axes: for every
    mix in a `mix_grid`, find the pod granularity that maximizes
    co-scheduled effective TOPS @TDP (the multi-tenant counterpart of the
    Fig-5 single-tenancy sweep in core/dse.py).

benchmarks/multitenancy.py (Fig-11 numbers + slice-accurate oracle) and
benchmarks/tenancy.py (the mix DSE) print these as metric rows.
"""

from __future__ import annotations

from ..core.dse import Design
from ..core.workloads import (bert, densenet, inception_v3, resnet)
from .mix import Tenant, TenantMix, mix_grid
from .planner import TIME_MUX, TenancyPlan, plan_mixes

# the paper's Fig-11 pairing: a pod-saturating CNN stream co-scheduled
# with pod-starved BERT streams (replicas=2: two tenant request streams —
# BERT at batch 1 strands most of the pods, so a second stream is free)
_FIG11_PAIR = (
    ("resnet50", lambda b: resnet(50, 224, batch=b), 1),
    ("bert-medium", lambda b: bert("medium", 100, batch=b), 2),
)


def fig11_mixes(batches: tuple[int, ...] = (1, 2, 4, 8)) -> list[TenantMix]:
    """ResNet-50 + 2x BERT-medium co-schedules, one mix per batch size.
    The gain over sequential shrinks as batch grows — batching alone also
    recovers utilization — which is Fig 11's batch-scaling story."""
    return [
        TenantMix(
            name=f"resnet50+bert-medium@b{b}",
            tenants=tuple(Tenant(name=f"{n}@b{b}", gemms=tuple(f(b)),
                                 replicas=r)
                          for n, f, r in _FIG11_PAIR))
        for b in batches
    ]


def fig11_sweep(
    pods: tuple[int, ...] = (128, 256),
    batches: tuple[int, ...] = (1, 2, 4, 8),
    policy: str = TIME_MUX,
    tdp: float = 400.0,
) -> list[list[TenancyPlan]]:
    """The batched Fig-11 grid on the paper's 32x32 pod: plans indexed
    [pod-count][batch], `parallel_gain` being the figure's headline."""
    designs: list[Design] = [(32, 32, "butterfly-2", p) for p in pods]
    return plan_mixes(fig11_mixes(batches), designs, policy, tdp)


# granularities from the paper's Fig-5/Table-2 candidate set; isopower pod
# counts (None -> largest power of two under TDP, as everywhere else)
_DSE_GRAN = ((16, 16), (20, 20), (32, 32), (48, 48),
             (64, 64), (128, 128), (256, 256), (512, 512))


def dse_designs(interconnect: str = "butterfly-2") -> list[Design]:
    return [(r, c, interconnect, None) for r, c in _DSE_GRAN]


def default_mixes(batches: tuple[int, ...] = (1,)) -> list[TenantMix]:
    """All pairs over a 5-workload suite (10 mixes at batch 1) — the
    tenant-mix axis for the DSE grid."""
    factories = {
        "resnet50": lambda b: resnet(50, 224, batch=b),
        "densenet121": lambda b: densenet(121, 224, batch=b),
        "inception-v3": lambda b: inception_v3(299, batch=b),
        "bert-medium": lambda b: bert("medium", 100, batch=b),
        "bert-large": lambda b: bert("large", 100, batch=b),
    }
    return mix_grid(factories, batches=batches, pair_size=2)


def mix_dse(
    mixes: list[TenantMix] | None = None,
    designs: list[Design] | None = None,
    policy: str = TIME_MUX,
    tdp: float = 400.0,
) -> dict[str, TenancyPlan]:
    """Best pod granularity per tenant mix (co-scheduled effective TOPS
    @TDP): the whole (designs x mixes) grid is one planner call; returns
    mix name -> winning plan."""
    mixes = default_mixes() if mixes is None else mixes
    designs = dse_designs() if designs is None else designs
    grid = plan_mixes(mixes, designs, policy, tdp)
    best: dict[str, TenancyPlan] = {}
    for row in grid:
        for plan in row:
            cur = best.get(plan.mix)
            if cur is None or plan.effective_tops_at_tdp > \
                    cur.effective_tops_at_tdp:
                best[plan.mix] = plan
    return best
