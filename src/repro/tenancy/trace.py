"""Trace bridge: serve/engine.py request streams -> GemmSpec tenants.

`ServeTraceRecorder` plugs into `ServeEngine(tracer=...)` and records the
engine's actual prefill / step-locked-decode events as it serves a request
stream. `trace_to_gemms` then lowers the recorded timeline to the same
GEMM-trace form as core/workloads.py: each prefill contributes the prompt's
projection/attention/FFN GEMMs at d1 = prompt length; each decode step
contributes the *fused* batched GEMMs the continuous batcher actually runs
(d1 = live lanes for the weight GEMMs — many tenants' decode GEMVs fused
into one GEMM is exactly the paper's §6.1 multi-tenant utilization
argument) plus the per-step attention reads at the lanes' true context
lengths.

The result feeds the co-schedule planner (tenancy/planner.py) with
realistic serving workloads instead of hand-written suite traces:

    rec = ServeTraceRecorder()
    engine = ServeEngine(model, params, tracer=rec)
    ... submit / run_to_completion ...
    t = trace_tenant("llm-serve", rec, model.cfg, slo_latency_s=1e-3)
    plans = plan_mixes([TenantMix("serve+cnn", (t, cnn_tenant))], designs)
"""

from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig
from ..core.tiling import GemmSpec
from ..core.workloads import _Trace
from ..obs.export import Span
from .mix import Tenant


@dataclasses.dataclass
class ServeTraceRecorder:
    """Engine-side event log; see ServeEngine(tracer=...) in serve/engine.py.

    Events are ("prefill", prompt_len, t) and ("decode", lanes, contexts,
    t) — the step-locked sequence the pods would see. `t` is the event's
    engine-relative start time; when the caller doesn't pass one (synthetic
    traces, older callers) a monotonically increasing record index stands
    in, so recording order is the time order. `trace_to_gemms` sorts on
    `t` before lowering: priority scheduling can *record* interleaved
    prefill/decode spans out of wall-clock order (a short-deadline lane's
    prefill lands between decode chunks that were recorded first), and the
    wave-model latency prediction is only faithful on the time-ordered
    stream.

    Events carry the GEMM-shaping facts (what `trace_to_gemms` lowers);
    `spans` additionally carry the host wall-clock of every device call
    the engine made (one span per prefill launch / fused decode chunk),
    which `obs.export.to_chrome_trace` turns into a Perfetto-loadable
    timeline and `obs.drift` pairs with the wave-model prediction.
    """

    events: list[tuple] = dataclasses.field(default_factory=list)
    spans: list[Span] = dataclasses.field(default_factory=list)

    def _stamp(self, t: float | None) -> float:
        return float(len(self.events)) if t is None else float(t)

    def on_prefill(self, rid: int, prompt_len: int,
                   t: float | None = None) -> None:
        self.events.append(("prefill", int(prompt_len), self._stamp(t)))

    def on_decode(self, lanes: int, contexts: list[int],
                  t: float | None = None) -> None:
        self.events.append(("decode", int(lanes),
                            tuple(int(c) for c in contexts),
                            self._stamp(t)))

    def on_span(self, name: str, ts: float, dur: float, cat: str = "serve",
                **args) -> None:
        self.spans.append(Span(name=name, ts=float(ts), dur=float(dur),
                               cat=cat, args=args))

    @property
    def num_prefills(self) -> int:
        return sum(1 for e in self.events if e[0] == "prefill")

    @property
    def num_decode_steps(self) -> int:
        return sum(1 for e in self.events if e[0] == "decode")

    def phase_seconds(self, cat: str) -> float:
        """Total host wall-clock spent in spans of category `cat`."""
        return sum(s.dur for s in self.spans if s.cat == cat)

    def phase_tokens(self, kind: str) -> int:
        """Tokens processed by events of `kind`: prompt tokens for
        prefills, emitted (per-lane) tokens for decode steps."""
        return sum(e[1] for e in self.events if e[0] == kind)


def _event_time(ev: tuple) -> float:
    """Start time of a recorded event (the tuple's trailing stamp);
    events appended without one (hand-built tuples) sort as t=0, which the
    stable sort keeps in recording order."""
    return ev[-1] if isinstance(ev[-1], float) else 0.0


def _layer_gemms(t: _Trace, cfg: ArchConfig, d1: int, attn_d1: int,
                 ctx: int, include_attention: bool) -> None:
    """One transformer layer's GEMMs at batch-rows d1 (fused lanes)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kv = max(1, cfg.n_kv_heads)
    prev = t._next - 1
    q = t.add(d1, d, cfg.n_heads * hd, deps=(prev,), name="q")
    k = t.add(d1, d, kv * hd, deps=(prev,), name="k")
    v = t.add(d1, d, kv * hd, deps=(prev,), name="v")
    last: tuple[int, ...] = (q, k, v)
    if include_attention and ctx > 0:
        sc = t.add(attn_d1, hd, ctx, deps=(q, k), name="qk")
        av = t.add(attn_d1, ctx, hd, deps=(sc, v), name="av")
        last = (av,)
    o = t.add(d1, cfg.n_heads * hd, d, deps=last, name="o")
    f1 = t.add(d1, d, cfg.d_ff, deps=(o,), name="ffn_up")
    t.add(d1, cfg.d_ff, d, deps=(f1,), name="ffn_down")


def trace_to_gemms(recorder: ServeTraceRecorder, cfg: ArchConfig,
                   include_attention: bool = True,
                   include_lm_head: bool = False,
                   kinds: tuple[str, ...] | None = None,
                   max_events: int | None = None) -> list[GemmSpec]:
    """Lower a recorded serving timeline to a GemmSpec stream.

    Events chain sequentially (the engine is step-locked: a prefill or a
    decode step must drain before the next step launches), layers chain
    within an event — the same dependency discipline as
    workloads.transformer_lm, with d1 set by what the engine actually
    batched rather than a hypothetical shape.

    `kinds` restricts the lowering to a subset of event kinds (e.g.
    ``("decode",)`` for the per-phase drift rows of obs/drift.py); the
    filtered events still chain sequentially among themselves.
    `max_events` caps the number of (filtered) events lowered — the
    slice-accurate scheduler the drift check runs is O(tiles), so drift
    sampling bounds it.

    Events are lowered in *start-time* order, not record order: admission
    policies that reorder lanes (serve/admission.py priority scheduling)
    may record a prefill span after decode chunks that started later, and
    the sequential-chain dependency discipline below is only correct on
    the time-ordered stream. The sort is stable, so events recorded
    without timestamps (synthetic traces) keep their recording order.
    """
    t = _Trace()
    events = sorted(recorder.events, key=_event_time)
    if kinds is not None:
        events = [e for e in events if e[0] in kinds]
    if max_events is not None:
        events = events[:max_events]
    for ev in events:
        if ev[0] == "prefill":
            seq = ev[1]
            for _ in range(cfg.n_layers):
                # prompt attention: all heads' (seq x hd) @ (hd x seq)
                # score GEMMs fused row-wise, like the decode events below
                _layer_gemms(t, cfg, d1=seq, attn_d1=seq * cfg.n_heads,
                             ctx=seq, include_attention=include_attention)
        else:
            lanes, contexts = ev[1], ev[2]
            ctx = max(1, round(sum(contexts) / len(contexts))) \
                if contexts else 0
            for _ in range(cfg.n_layers):
                # decode: weight GEMMs fuse all live lanes into d1 = lanes;
                # attention reads are per-lane-per-head GEMVs at the mean
                # context length of the step's lanes
                _layer_gemms(t, cfg, d1=lanes,
                             attn_d1=lanes * cfg.n_heads, ctx=ctx,
                             include_attention=include_attention)
        if include_lm_head and cfg.vocab:
            # ev[1] is rows either way: prompt length or fused lanes
            t.add(ev[1], cfg.d_model, cfg.vocab, name="lm_head")
    return t.gemms


def request_gemms(cfg: ArchConfig, prompt_len: int, new_tokens: int,
                  lanes: int = 1, include_attention: bool = True,
                  include_lm_head: bool = False) -> list[GemmSpec]:
    """The GEMM stream ONE request would put through the engine: a
    prefill event at the prompt length followed by `new_tokens` decode
    steps at growing context — the same lowering `trace_to_gemms` applies
    to recorded timelines, built *predictively* for a request that has not
    run yet. `lanes` prices the decode steps as if fused with that many
    live lanes (1 = the request decodes alone, the conservative admission
    estimate). This is the admission controller's per-request cost model
    (serve/admission.py): the wave model turns it into predicted service
    seconds, so `TenancyPlan.slo_attainment`-style SLO accounting can
    *choose* admission instead of only reporting after the fact."""
    rec = ServeTraceRecorder()
    rec.on_prefill(0, prompt_len)
    for s in range(max(0, int(new_tokens))):
        rec.on_decode(lanes, [prompt_len + s] * lanes)
    return trace_to_gemms(rec, cfg, include_attention=include_attention,
                          include_lm_head=include_lm_head)


def trace_tenant(name: str, recorder: ServeTraceRecorder, cfg: ArchConfig,
                 replicas: int = 1, slo_latency_s: float | None = None,
                 **kw) -> Tenant:
    """Recorded serving stream as a planner Tenant (see tenancy/mix.py)."""
    gemms = trace_to_gemms(recorder, cfg, **kw)
    if not gemms:
        wanted = kw.get("kinds") or ("prefill", "decode")
        recorded = sorted({e[0] for e in recorder.events})
        missing = [k for k in wanted if k not in recorded] or list(wanted)
        raise ValueError(
            f"tenant {name!r}: recorder saw no {'/'.join(missing)} events"
            f" (recorded phases: {', '.join(recorded) if recorded else 'none'})"
            " — construct the engine with ServeEngine(tracer=recorder) (the"
            " `tracer` kwarg) and run it through the missing phase before"
            " lowering the trace")
    return Tenant(name=name, gemms=tuple(gemms), replicas=replicas,
                  slo_latency_s=slo_latency_s)
