from .optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw, lr_schedule
from .train_step import TrainConfig, make_eval_step, make_train_step
from .checkpoint import (latest_step, prune_checkpoints, restore_checkpoint,
                         save_checkpoint)
from .data import DataConfig, batches
from .fault import ElasticMesh, Heartbeat, StragglerPolicy
