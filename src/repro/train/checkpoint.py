"""Sharded checkpointing with atomic two-phase commit + resume.

Layout:  <dir>/step_<N>/
           meta.json              (step, tree structure, shapes, dtypes)
           shard_<host>.npz       (this host's param/optimizer leaves)
           COMMITTED              (written last — a checkpoint without it
                                   is torn and ignored on restore)

Fault-tolerance contract (train/fault.py): any host can die at any point;
restore picks the newest COMMITTED step. Writes go to a temp dir +
os.replace, so a crash mid-save never corrupts the previous checkpoint.
On multi-host JAX each host saves its addressable shards; here (single
host) that is the whole tree.

Integrity contract (the SDC story's at-rest leg): meta.json carries a
sha256 per shard file, computed from the bytes on disk after the write.
Restore re-hashes before np.load and raises the typed `CheckpointCorrupt`
naming the damaged file on any mismatch or unreadable archive — a torn
or bit-rotted shard can never be silently loaded into training state.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A committed checkpoint failed integrity validation on restore.
    `path` names the corrupt file; `detail` says how it failed."""

    def __init__(self, path: str, detail: str):
        self.path = path
        self.detail = detail
        super().__init__(f"corrupt checkpoint file {path}: {detail}")


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, host: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    try:
        leaves = _leaf_paths(tree)
        arrays = {}
        dtypes = {}
        for k, v in leaves:
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.kind not in "biufc":   # bf16 etc: store raw bits
                a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
            arrays[k] = a
        shard = f"shard_{host}.npz"
        np.savez(os.path.join(tmp, shard), **arrays)
        meta = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "dtypes": dtypes,
            "shapes": {k: list(np.asarray(v).shape) for k, v in leaves},
            # content checksum of the shard bytes actually on disk —
            # validated by restore before np.load touches the archive
            "checksums": {shard: _sha256_file(os.path.join(tmp, shard))},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, step: int | None = None,
                       host: int = 0):
    """Restore into the structure of `tree_like` (shapes must match)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"checkpoint at step {step} not committed")
    import json as _json
    import ml_dtypes
    with open(os.path.join(path, "meta.json")) as f:
        meta = _json.load(f)
    shard = f"shard_{host}.npz"
    shard_path = os.path.join(path, shard)
    # integrity gate: re-hash the shard bytes against the digest recorded
    # at save time (pre-checksum checkpoints carry no "checksums" key and
    # skip the gate); only then hand the archive to np.load, and wrap any
    # parse failure so the caller learns WHICH file is damaged
    want_sum = meta.get("checksums", {}).get(shard)
    if want_sum is not None:
        got_sum = _sha256_file(shard_path)
        if got_sum != want_sum:
            raise CheckpointCorrupt(
                shard_path, f"sha256 mismatch (expected {want_sum[:12]}…, "
                            f"got {got_sum[:12]}…)")
    try:
        data = np.load(shard_path)
    except FileNotFoundError:
        raise
    except Exception as err:
        raise CheckpointCorrupt(shard_path, f"unreadable archive: {err}")
    leaves = _leaf_paths(tree_like)
    flat_restored = []
    for key, like in leaves:
        arr = data[key]
        want = meta["dtypes"].get(key, str(arr.dtype))
        if str(arr.dtype) != want:            # raw-bit dtypes (bf16, fp8)
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        assert tuple(arr.shape) == tuple(np.shape(like)), (
            key, arr.shape, np.shape(like))
        flat_restored.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, flat_restored), step


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
