"""Sharded checkpointing with atomic two-phase commit + resume.

Layout:  <dir>/step_<N>/
           meta.json              (step, tree structure, shapes, dtypes)
           shard_<host>.npz       (this host's param/optimizer leaves)
           COMMITTED              (written last — a checkpoint without it
                                   is torn and ignored on restore)

Fault-tolerance contract (train/fault.py): any host can die at any point;
restore picks the newest COMMITTED step. Writes go to a temp dir + rename,
so a crash mid-save never corrupts the previous checkpoint. On multi-host
JAX each host saves its addressable shards; here (single host) that is the
whole tree.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, host: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    try:
        leaves = _leaf_paths(tree)
        arrays = {}
        dtypes = {}
        for k, v in leaves:
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.kind not in "biufc":   # bf16 etc: store raw bits
                a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
            arrays[k] = a
        np.savez(os.path.join(tmp, f"shard_{host}.npz"), **arrays)
        meta = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "dtypes": dtypes,
            "shapes": {k: list(np.asarray(v).shape) for k, v in leaves},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, step: int | None = None,
                       host: int = 0):
    """Restore into the structure of `tree_like` (shapes must match)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"checkpoint at step {step} not committed")
    import json as _json
    import ml_dtypes
    with open(os.path.join(path, "meta.json")) as f:
        meta = _json.load(f)
    data = np.load(os.path.join(path, f"shard_{host}.npz"))
    leaves = _leaf_paths(tree_like)
    flat_restored = []
    for key, like in leaves:
        arr = data[key]
        want = meta["dtypes"].get(key, str(arr.dtype))
        if str(arr.dtype) != want:            # raw-bit dtypes (bf16, fp8)
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        assert tuple(arr.shape) == tuple(np.shape(like)), (
            key, arr.shape, np.shape(like))
        flat_restored.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, flat_restored), step


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
