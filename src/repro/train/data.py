"""Deterministic synthetic token pipeline.

Reproducible across restarts (sequence index -> tokens is a pure function
of (seed, step, host)), sharded per host, with background-style prefetch
(here: an iterator that builds the next batch eagerly). A real deployment
swaps `_synth_tokens` for a tokenized shard reader; everything else stays.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _synth_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """Markov-ish synthetic text: deterministic in (seed, step, host)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    b, s = cfg.host_batch, cfg.seq_len
    base = rng.integers(0, cfg.vocab, (b, s), dtype=np.int32)
    # inject local structure so the loss is learnable (copy-prev patterns)
    shift = np.roll(base, 1, axis=1)
    mask = rng.random((b, s)) < 0.5
    return np.where(mask, shift, base).astype(np.int32)


def batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Yields {tokens, labels} with next-token labels; resume-safe: pass the
    restored step and the stream continues identically."""
    step = start_step
    while True:
        toks = _synth_tokens(cfg, step)
        labels = np.concatenate(
            [toks[:, 1:], np.full((toks.shape[0], 1), -1, np.int32)], axis=1)
        yield {"tokens": toks, "labels": labels}
        step += 1
