"""Fault tolerance & elasticity manager (1000+-node posture).

What runs where:
  * checkpoint/restart  — checkpoint.py (atomic commit, newest-COMMITTED
    restore); the train loop (launch/train.py) saves every N steps and
    resumes from the newest checkpoint, with the data stream keyed by step
    so no batch is skipped or repeated.
  * failure detection   — `Heartbeat`: hosts stamp a monotonically
    increasing step; a host silent for `timeout_steps` is declared dead.
    (On a real fleet this is the TPU runtime's health service; the object
    boundary is identical.)
  * elastic re-mesh     — `ElasticMesh.next_mesh()`: on failure, fall back
    to the largest power-of-two slice of surviving hosts and rebuild the
    (pod, data, model) mesh; TP degree is preserved (model-parallel groups
    must stay intact — a dead host kills its whole TP group), DP shrinks.
    Global batch is preserved by raising grad-accum microbatches — the same
    math, fewer chips (and the SOSA tiling argument says utilization holds
    as long as #tiles >= #pods, which shrinking pods only helps).
  * straggler mitigation — `StragglerPolicy`: per-step duration EWMA; a
    host slower than `slow_factor` x median for `patience` steps is evicted
    like a failure (re-mesh without it). This mirrors the SOSA scheduler's
    slice re-assignment: work is slice-shaped and owner-agnostic, so
    eviction costs one checkpoint restore, not a cold start.

`Ewma` is the shared smoothing primitive: StragglerPolicy tracks one per
host, and the serving chaos harness (serve/chaos.py) reuses it for
slow-decode-chunk detection — same strike/patience discipline, one
implementation.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional


@dataclasses.dataclass
class Ewma:
    """Exponentially weighted moving average with a first-sample seed.

    ``observe`` folds a sample in and returns the updated average; before
    any sample, ``value`` is None (callers treat the stream as unwarmed
    rather than biased toward 0).
    """

    alpha: float = 0.3
    value: Optional[float] = None

    def observe(self, sample: float) -> float:
        self.value = float(sample) if self.value is None else \
            (1.0 - self.alpha) * self.value + self.alpha * float(sample)
        return self.value


@dataclasses.dataclass
class Heartbeat:
    num_hosts: int
    timeout_steps: int = 3
    _last_step: dict = dataclasses.field(default_factory=dict)

    def beat(self, host: int, step: int) -> None:
        self._last_step[host] = step

    def dead_hosts(self, current_step: int) -> list[int]:
        return [h for h in range(self.num_hosts)
                if current_step - self._last_step.get(h, -1)
                > self.timeout_steps]


@dataclasses.dataclass
class StragglerPolicy:
    slow_factor: float = 2.0
    patience: int = 3
    _ewma: dict = dataclasses.field(default_factory=dict)
    _strikes: dict = dataclasses.field(default_factory=dict)

    def observe(self, host: int, step_seconds: float) -> None:
        self._ewma.setdefault(host, Ewma(alpha=0.3)).observe(step_seconds)

    def stragglers(self) -> list[int]:
        if len(self._ewma) < 2:
            return []
        vals = sorted(e.value for e in self._ewma.values())
        med = vals[len(vals) // 2]
        out = []
        for h, e in self._ewma.items():
            if e.value > self.slow_factor * med:
                self._strikes[h] = self._strikes.get(h, 0) + 1
                if self._strikes[h] >= self.patience:
                    out.append(h)
            else:
                self._strikes[h] = 0
        return out


@dataclasses.dataclass
class ElasticMesh:
    """Tracks healthy hosts; yields the mesh shape to rebuild with."""
    total_hosts: int
    tp_degree: int                      # model-parallel ways (kept intact)
    hosts_per_pod: int
    healthy: Optional[set] = None

    def __post_init__(self):
        if self.healthy is None:
            self.healthy = set(range(self.total_hosts))

    def fail(self, host: int) -> None:
        self.healthy.discard(host)

    def next_mesh(self) -> dict:
        """Largest power-of-two surviving slice, TP preserved."""
        n = len(self.healthy)
        usable = 2 ** int(math.floor(math.log2(max(1, n))))
        # chips = hosts (abstracted 1:1 here); DP ways shrink, TP fixed
        dp = max(1, usable // self.tp_degree)
        pods = max(1, dp // max(1, self.hosts_per_pod // self.tp_degree))
        return {"pod": min(pods, 2), "data": dp // min(pods, 2),
                "model": self.tp_degree}

    def microbatch_scale(self, original_dp: int) -> int:
        """Grad-accum factor to keep the global batch constant."""
        new_dp = self.next_mesh()["pod"] * self.next_mesh()["data"]
        return max(1, original_dp // new_dp)
