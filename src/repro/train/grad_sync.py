"""Cross-pod gradient synchronization — the paper's interconnect pillar as
a first-class training feature.

On the multi-pod mesh only data-parallel gradient sums cross the `pod`
axis (DCN-grade links). This module provides drop-in reducers for that
axis, selectable per deployment:

    "psum"        — XLA default (torus-optimal rings on ICI; baseline)
    "butterfly"   — log2(N)-round recursive doubling (parallel/collectives):
                    latency-optimal for the many *small* tensors a
                    SOSA-granularity fleet produces (the paper's Butterfly
                    argument transplanted to collectives)
    "compressed"  — int8 block-quantized psum with error feedback
                    (parallel/compression): 4x fewer bytes on the slowest
                    links; the error-feedback state rides in the optimizer
                    carry so compressed SGD stays unbiased across steps

All reducers run under shard_map over the reduction axis and are
numerically validated against plain psum in tests/test_grad_sync.py.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..parallel.collectives import (butterfly_all_reduce,
                                    butterfly_all_reduce_expansion2)
from ..parallel.compression import compressed_psum


def _flatten_grads(grads):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [(l.shape, l.dtype, l.size) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, shapes, treedef


def _unflatten_grads(flat, shapes, treedef):
    out = []
    off = 0
    for shape, dtype, size in shapes:
        out.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def make_grad_sync(mesh: Mesh, axis: str = "pod", impl: str = "psum"):
    """Returns sync(grads, error) -> (reduced_grads, new_error).

    grads must be replicated along `axis` up to the missing sum (i.e. each
    pod holds its local-batch gradient); other axes' sharding is preserved
    by flattening per-shard (the reducer runs pointwise per shard).
    `error` is the error-feedback carry for "compressed" (None otherwise).
    """
    if axis not in mesh.shape:
        return lambda grads, error=None: (grads, error)

    def sync(grads, error=None):
        flat, shapes, treedef = _flatten_grads(grads)

        if impl == "psum":
            def red(x, e):
                return jax.lax.psum(x, axis), e
        elif impl == "butterfly":
            def red(x, e):
                return butterfly_all_reduce(x, axis), e
        elif impl == "butterfly2":
            def red(x, e):
                return butterfly_all_reduce_expansion2(x, axis), e
        elif impl == "compressed":
            def red(x, e):
                r, ne = compressed_psum(x, axis, e)
                return r, ne
        else:
            raise ValueError(impl)

        if error is None and impl == "compressed":
            error = jnp.zeros_like(flat)

        other_axes = tuple(a for a in mesh.axis_names if a != axis)
        spec = P(other_axes if len(other_axes) > 1 else
                 (other_axes[0] if other_axes else None))

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(spec, spec if error is not None else P()),
            out_specs=(spec, spec if error is not None else P()),
            check_rep=False)
        def run(x, e):
            r, ne = red(x, e if error is not None else None)
            return r, (ne if ne is not None else jnp.zeros((), x.dtype))

        # pad so the flat vector divides the non-reduction shards
        import math
        denom = math.prod(mesh.shape[a] for a in other_axes) or 1
        pad = (-flat.shape[0]) % denom
        if pad:
            flat = jnp.pad(flat, (0, pad))
            if error is not None:
                error = jnp.pad(error, (0, pad))
        red_flat, new_error = run(flat, error if error is not None else
                                  jnp.zeros((), flat.dtype))
        if pad:
            red_flat = red_flat[:-pad]
            if error is not None:
                new_error = new_error[:-pad]
        return _unflatten_grads(red_flat, shapes, treedef), \
            (new_error if error is not None else None)

    return sync
