"""Cross-pod gradient synchronization — the paper's interconnect pillar as
a first-class training feature.

On the multi-pod mesh only data-parallel gradient sums cross the `pod`
axis (DCN-grade links). This module provides drop-in reducers for that
axis, selectable per deployment:

    "psum"        — XLA default (torus-optimal rings on ICI; baseline)
    "butterfly"   — log2(N)-round recursive doubling (parallel/collectives):
                    latency-optimal for the many *small* tensors a
                    SOSA-granularity fleet produces (the paper's Butterfly
                    argument transplanted to collectives)
    "compressed"  — int8 block-quantized psum with error feedback
                    (parallel/compression): 4x fewer bytes on the slowest
                    links; the error-feedback state rides in the optimizer
                    carry so compressed SGD stays unbiased across steps

All reducers run under shard_map over the reduction axis and are
numerically validated against plain psum in tests/test_grad_sync.py.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..parallel.collectives import (butterfly_all_reduce,
                                    butterfly_all_reduce_expansion2)
from ..parallel.compression import compressed_psum


def _flatten_grads(grads):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    shapes = [(l.shape, l.dtype, l.size) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, shapes, treedef


def _unflatten_grads(flat, shapes, treedef):
    out = []
    off = 0
    for shape, dtype, size in shapes:
        out.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def make_grad_sync(mesh: Mesh, axis: str = "pod", impl: str = "psum"):
    """Returns sync(grads, error) -> (reduced_grads, new_error).

    grads must be replicated along `axis` up to the missing sum (i.e. each
    pod holds its local-batch gradient). The flattened gradient vector is
    REPLICATED on every device while reducing (P(None) specs): sharding it
    over the non-reduction axes miscompiles on jax<=0.4.37 (see the spec
    comment below), so each device temporarily materializes the full fp32
    flat vector — budget memory accordingly on large models.
    `error` is the error-feedback carry for "compressed" (None otherwise).
    """
    if axis not in mesh.shape:
        return lambda grads, error=None: (grads, error)

    def sync(grads, error=None):
        flat, shapes, treedef = _flatten_grads(grads)

        if impl == "psum":
            def red(x, e):
                return jax.lax.psum(x, axis), e
        elif impl == "butterfly":
            def red(x, e):
                return butterfly_all_reduce(x, axis), e
        elif impl == "butterfly2":
            def red(x, e):
                return butterfly_all_reduce_expansion2(x, axis), e
        elif impl == "compressed":
            def red(x, e):
                r, ne = compressed_psum(x, axis, e)
                return r, ne
        else:
            raise ValueError(impl)

        if error is None and impl == "compressed":
            error = jnp.zeros_like(flat)

        # The reducer sees the full flat vector on every device (P(None)):
        # sharding it over the non-reduction axes (P(other_axes)) miscompiles
        # under jit on jax<=0.4.37 — a concatenate feeding shard_map with
        # check_rep=False loses the pod-replication guarantee and the psum
        # over-reduces (2x/4x too large). Replication is always correct;
        # data-parallel grads are replicated along `axis` by construction.
        spec = P(None)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(spec, spec if error is not None else P()),
            out_specs=(spec, spec if error is not None else P()),
            check_rep=False)
        def run(x, e):
            r, ne = red(x, e if error is not None else None)
            return r, (ne if ne is not None else jnp.zeros((), x.dtype))

        # no padding needed: the replicated spec places the whole vector on
        # every device, so there is no shard-divisibility constraint
        red_flat, new_error = run(flat, error if error is not None else
                                  jnp.zeros((), flat.dtype))
        return _unflatten_grads(red_flat, shapes, treedef), \
            (new_error if error is not None else None)

    return sync
