"""AdamW with mixed precision and ZeRO-1-shardable state.

State layout: fp32 master params + fp32 m/v moments, all plain pytrees so
the launcher can place them with `parallel.sharding.zero1_pspec` (moments
sharded across the DP axes — the ZeRO-1 trick; working params stay bf16 and
TP-sharded only). Pure functions; no optax dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any      # fp32 params
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moments in bf16 halve optimizer HBM (340B: 2.7 TB -> 1.35 TB) at a
    # small noise cost; master params stay fp32 (the accuracy-critical part)
    moment_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_adamw(params, cfg: AdamWConfig | None = None) -> AdamWState:
    mdt = jnp.float32 if cfg is None or cfg.moment_dtype == "float32" \
        else jnp.bfloat16
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    return AdamWState(jnp.zeros((), jnp.int32), master, zeros(), zeros())


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, state: AdamWState, grads,
                 compute_dtype=jnp.bfloat16):
    """One step. grads may be bf16; moments/master update in fp32.
    Returns (new_params_compute_dtype, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.float32 if cfg.moment_dtype == "float32" else jnp.bfloat16

    def upd(p32, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / b1c
        vh = v32 / b2c
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32, m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(state.master)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    master = treedef.unflatten([o[0] for o in out])
    m = treedef.unflatten([o[1] for o in out])
    v = treedef.unflatten([o[2] for o in out])
    params = jax.tree.map(lambda p: p.astype(compute_dtype), master)
    return params, AdamWState(step, master, m, v), {
        "grad_norm": gnorm, "lr": lr}
