"""Training step: bf16 compute, remat over layers, microbatch grad-accum
(compute/comm overlap: XLA pipelines the DP gradient reduction of
microbatch i with the compute of microbatch i+1 when accumulation is a
scan — the paper's 'hide data movement under compute' at mesh scale)."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import AdamWConfig, AdamWState, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    # remat is per-layer inside the model (Model(remat=True)); this flag
    # adds an additional whole-microbatch checkpoint (rarely needed).
    remat: bool = False
    optimizer: AdamWConfig = AdamWConfig()


def loss_fn(model: Model, params, batch):
    return model.loss(params, batch)


def _split_micro(batch, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def grads_fn(model: Model, tcfg: TrainConfig):
    """Returns f(params, batch) -> (loss, grads) with microbatching."""
    base = functools.partial(loss_fn, model)
    if tcfg.remat:
        # remat the per-microbatch forward; the scan-over-layers inside the
        # model already bounds live activations to O(1 layer)
        base = jax.checkpoint(base, static_argnums=())
    vg = jax.value_and_grad(base)

    if tcfg.microbatches == 1:
        return vg

    def accum(params, batch):
        micro = _split_micro(batch, tcfg.microbatches)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = vg(params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (loss_acc + loss, g_acc), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / tcfg.microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return accum


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    gf = grads_fn(model, tcfg)

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = gf(params, batch)
        params, opt_state, om = adamw_update(tcfg.optimizer, opt_state, grads)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step
