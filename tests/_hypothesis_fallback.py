"""Deterministic fallback for the subset of `hypothesis` the tests use.

The container image does not ship `hypothesis`, and the test suite must not
silently lose its property tests when it is absent.  This module implements
just enough of the API — `given`, `settings`, and the strategies the suite
draws from (`integers`, `sampled_from`, `booleans`, `permutations`) — to run
each property test over a fixed number of pseudo-random samples seeded from
the test's name, so failures are reproducible.

Test modules import it as:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st

When the real `hypothesis` is installed it wins, and this module is unused.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib


class _Strategy:
    """A strategy is just a draw function: rng -> value."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _permutations(values) -> _Strategy:
    pool = list(values)
    return _Strategy(lambda rng: rng.sample(pool, len(pool)))


def _floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(
        lambda rng: [elements.draw(rng)
                     for _ in range(rng.randint(min_size, max_size))])


st = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    booleans=_booleans,
    permutations=_permutations,
    floats=_floats,
    lists=_lists,
)
strategies = st

_DEFAULT_EXAMPLES = 10
_MAX_EXAMPLES_CAP = 30  # keep the fallback fast; hypothesis shrinks, we can't


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
    """Records max_examples; all other hypothesis settings are no-ops here."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_EXAMPLES)
            n = min(n, _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                pos = [s.draw(rng) for s in arg_strategies]
                kw = {name: s.draw(rng) for name, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kw)

        # `@settings` may be applied above `@given`; it then tags the wrapper,
        # which is why max_examples is read off `wrapper` at call time.
        #
        # Hide the strategy-filled parameters from pytest (it would otherwise
        # look for fixtures named after them): expose only the leftover
        # params, like hypothesis does. Positional strategies fill the
        # rightmost positional parameters.
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[:-len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper

    return deco
