"""Suite-wide fixtures.

The full suite compiles thousands of XLA programs in one process (every
engine/test builds fresh jitted closures). On the 1-core CI box the
accumulated executable state eventually segfaults XLA's CPU compiler
mid-`backend_compile` (deterministically, ~250 tests in — the crashing
program compiles fine in isolation). Dropping the dead jit caches at
module boundaries bounds that state; per-module compile-count
assertions (engine `_cache_size`, `choose_blocks.cache_info`) are
unaffected because they never span modules.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_state_per_module():
    yield
    jax.clear_caches()
    gc.collect()
