"""Overload & failure semantics: SLO-aware admission, deadlines,
backpressure, and the seeded chaos harness (serve/admission.py,
serve/chaos.py) threaded through both serving engines.

The contract under test:
  * malformed requests raise typed `InvalidRequest` at submit, naming the
    offending field — they never reach the hot loop;
  * every submitted request reaches exactly ONE terminal state
    (done | rejected | expired), chaos or not, and slot occupancy returns
    to zero at drain (no leaks);
  * a wedged engine raises `ServeStalled` naming the stuck requests
    instead of returning silently from run_to_completion;
  * the default engine (fifo, unbounded, no deadlines, no chaos) is
    bit-identical to the seed: same tokens, same jit cache sizes, same
    host-sync count (the PR 7 discipline);
  * under deterministic 2x overload (virtual time) edf and slo-aware beat
    fifo on SLO attainment;
  * injected transient faults retry with backoff and heal; retries
    exhausted sheds the affected requests with their slots reclaimed; and
    every request a chaos engine completes carries token-exact output vs
    the bare ReferenceEngine oracle.
"""

import math

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch, reduced
from repro.models.model import Model
from repro.serve.admission import (AdmissionConfig, AdmissionController,
                                   InvalidRequest, ServeStalled,
                                   TERMINAL_STATES, WaveLatencyPredictor)
from repro.serve.chaos import (ChaosConfig, FaultInjector, SlowChunkDetector,
                               TransientDeviceError, VirtualClock)
from repro.serve.engine import Request, ServeEngine
from repro.serve.reference import ReferenceEngine
from repro.train.fault import Ewma


@pytest.fixture(scope="module")
def parts():
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab, n,
                                                dtype=np.int32)


def _drain(eng, reqs, max_steps=2000):
    eng.run_to_completion(max_steps=max_steps)
    assert not any(eng.active), "slot leak: occupancy nonzero at drain"
    assert not eng.queue
    for r in reqs:
        assert r.state in TERMINAL_STATES, (r.rid, r.state)
    return {r.rid: list(r.out) for r in reqs}


# --------------------------------------------------------------------------
# satellite: typed validation at submit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [ServeEngine, ReferenceEngine])
def test_submit_rejects_malformed_requests_with_typed_errors(parts,
                                                             engine_cls):
    cfg, model, params = parts
    eng = engine_cls(model, params, slots=2, max_len=32)
    cases = [
        (Request(rid=0, prompt=np.zeros(0, np.int32)), "prompt"),
        (Request(rid=1, prompt=_prompt(cfg, 33)), "prompt"),
        (Request(rid=2, prompt=_prompt(cfg, 4), max_new_tokens=0),
         "max_new_tokens"),
        (Request(rid=3, prompt=_prompt(cfg, 4), max_new_tokens=-2),
         "max_new_tokens"),
        (Request(rid=4, prompt=_prompt(cfg, 4), deadline_s=0.0),
         "deadline_s"),
        (Request(rid=5, prompt=_prompt(cfg, 4), deadline_s=-1.0),
         "deadline_s"),
    ]
    for req, field in cases:
        with pytest.raises(InvalidRequest) as ei:
            eng.submit(req)
        assert ei.value.field == field
        assert field in str(ei.value)
        # the reject never entered the system
        assert not eng.queue and req.state == "new"
    assert eng.admission.counts["submitted"] == 0
    # boundary: prompt length == max_len is VALID (retires with the
    # prefill token, the existing cache-full contract)
    ok = Request(rid=9, prompt=_prompt(cfg, 32), max_new_tokens=2)
    eng.submit(ok)
    eng.run_to_completion(max_steps=50)
    assert ok.done and ok.state == "done" and len(ok.out) == 1


def test_admission_config_validation():
    with pytest.raises(ValueError, match="unknown admission policy"):
        AdmissionConfig(policy="lifo")
    with pytest.raises(ValueError, match="max_queue"):
        AdmissionConfig(max_queue=0)


# --------------------------------------------------------------------------
# satellite: ServeStalled on exhausted max_steps
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [ServeEngine, ReferenceEngine])
def test_wedged_engine_raises_serve_stalled(parts, engine_cls):
    cfg, model, params = parts
    eng = engine_cls(model, params, slots=1, max_len=32)
    r = Request(rid=7, prompt=_prompt(cfg, 4), max_new_tokens=4)
    eng.submit(r)
    eng._admit = lambda: None          # wedge: admission never runs
    with pytest.raises(ServeStalled) as ei:
        eng.run_to_completion(max_steps=5)
    assert ei.value.pending == {7: "queued"}
    assert ei.value.max_steps == 5
    assert "rid 7: queued" in str(ei.value)


def test_run_to_completion_still_returns_cleanly_when_drained(parts):
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=2, max_len=32)
    r = Request(rid=0, prompt=_prompt(cfg, 5), max_new_tokens=3)
    eng.submit(r)
    eng.run_to_completion(max_steps=200)       # no raise
    assert r.done and len(r.out) == 3


# --------------------------------------------------------------------------
# the PR 7-style no-change gate: default engine == seed, bit for bit
# --------------------------------------------------------------------------

def test_fifo_no_faults_is_bit_identical_to_seed(parts, monkeypatch):
    """Default-constructed engine vs one with every new knob at its
    explicit default: same tokens, same jit cache sizes, same host-sync
    count (counted as np.asarray on jax.Array, the PR 7 accounting)."""
    import repro.serve.engine as engine_mod
    from test_serving import _SyncCountingNumpy
    cfg, model, params = parts
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9, 17, 12, 7)]

    counts, outs = {}, {}
    for name, kw in (("bare", {}),
                     ("threaded", {"admission": AdmissionConfig(
                         policy="fifo"), "max_retries": 3})):
        proxy = _SyncCountingNumpy(np)
        monkeypatch.setattr(engine_mod, "np", proxy)
        eng = ServeEngine(model, params, slots=2, max_len=64,
                          decode_chunk=8, **kw)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion(max_steps=500)
        monkeypatch.setattr(engine_mod, "np", np)
        counts[name] = (eng._prefill_fn._cache_size(),
                        eng._decode_fn._cache_size(), proxy.syncs)
        outs[name] = {r.rid: list(r.out) for r in reqs}
        assert all(r.done and r.state == "done" for r in reqs)
    assert outs["threaded"] == outs["bare"]
    assert counts["threaded"] == counts["bare"], (
        f"admission changed (prefill compiles, decode compiles, syncs): "
        f"{counts}")


def test_fifo_tokens_match_reference_oracle(parts):
    cfg, model, params = parts
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (4, 9, 6)]
    outs = {}
    for cls in (ServeEngine, ReferenceEngine):
        eng = cls(model, params, slots=2, max_len=32)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        outs[cls.__name__] = _drain(eng, reqs)
    assert outs["ServeEngine"] == outs["ReferenceEngine"]


# --------------------------------------------------------------------------
# deadlines, backpressure, degradation
# --------------------------------------------------------------------------

def test_queued_requests_expire_past_deadline(parts):
    """More work than one slot can serve before the deadline: the tail of
    the queue expires (terminal `expired`, reason queued-past-deadline)
    rather than being served late or leaking."""
    cfg, model, params = parts
    clk = VirtualClock()
    eng = ServeEngine(model, params, slots=1, max_len=32, clock=clk,
                      admission=AdmissionConfig(policy="edf"),
                      chaos=ChaosConfig(seed=0, service_seconds=0.2))
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4, i), max_new_tokens=4,
                    deadline_s=0.5) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    _drain(eng, reqs)
    states = {r.state for r in reqs}
    assert "expired" in states and "done" in states
    expired = [r for r in reqs if r.state == "expired"]
    assert all(r.reason == "queued-past-deadline" and not r.done
               and r.out == [] for r in expired)
    assert eng.admission.slo_attainment < 1.0


def test_running_request_expires_at_chunk_sync(parts):
    """A deadline that passes mid-decode is enforced at the existing
    chunk sync: the lane is reclaimed, tokens already emitted stay."""
    cfg, model, params = parts
    clk = VirtualClock()
    eng = ServeEngine(model, params, slots=1, max_len=64, decode_chunk=4,
                      clock=clk, admission=AdmissionConfig(policy="edf"),
                      chaos=ChaosConfig(seed=0, service_seconds=0.3))
    r = Request(rid=0, prompt=_prompt(cfg, 4), max_new_tokens=32,
                deadline_s=0.5)
    eng.submit(r)
    _drain(eng, [r])
    assert r.state == "expired" and r.reason == "deadline-exceeded"
    assert not r.done
    assert 1 <= len(r.out) < 32            # partial output survives


def test_bounded_queue_sheds_with_queue_full(parts):
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=1, max_len=32,
                      admission=AdmissionConfig(max_queue=2))
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4, i), max_new_tokens=2)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    # nothing admits between submits: the first two queue, the rest shed
    shed = [r for r in reqs if r.state == "rejected"]
    assert len(shed) == 3 and all(r.reason == "queue-full" for r in shed)
    _drain(eng, reqs)
    assert sum(1 for r in reqs if r.state == "done") == 2
    c = eng.admission.counts
    assert c["submitted"] == 5 and c["rejected"] == 3 and c["done"] == 2


def test_slo_aware_degrades_budgets_under_overload(parts):
    """Deep queue + slo-aware: newly admitted requests get shrunk decode
    budgets (graceful degradation) and everyone still terminates."""
    cfg, model, params = parts
    clk = VirtualClock()
    eng = ServeEngine(model, params, slots=1, max_len=64, clock=clk,
                      admission=AdmissionConfig(
                          policy="slo-aware", overload_queue_per_slot=2.0),
                      chaos=ChaosConfig(seed=0, service_seconds=0.01))
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4, i), max_new_tokens=9)
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    _drain(eng, reqs)
    assert eng.admission.counts["degraded"] > 0
    assert any(r.state == "done" and len(r.out) < 9 for r in reqs)
    assert all(r.state == "done" for r in reqs)   # degraded, not dropped


def test_edf_and_slo_aware_beat_fifo_attainment_at_overload(parts):
    """The headline acceptance: deterministic 2x overload in virtual
    time, mixed tight/loose deadlines — deadline-aware policies must beat
    arrival order on SLO attainment."""
    cfg, model, params = parts

    def run(policy):
        clk = VirtualClock()
        eng = ServeEngine(model, params, slots=2, max_len=64,
                          decode_chunk=8, clock=clk,
                          admission=AdmissionConfig(policy=policy),
                          chaos=ChaosConfig(seed=0, service_seconds=0.05))
        rng = np.random.default_rng(11)
        reqs = []
        for i in range(12):
            p = rng.integers(0, cfg.vocab, int(rng.integers(5, 9)),
                             dtype=np.int32)
            reqs.append(Request(rid=i, prompt=p, max_new_tokens=6,
                                deadline_s=0.8 if i % 2 else 7.0))
        for r in reqs:
            eng.submit(r)
        _drain(eng, reqs)
        return eng.admission.slo_attainment

    att = {p: run(p) for p in ("fifo", "edf", "slo-aware")}
    assert att["edf"] > att["fifo"], att
    assert att["slo-aware"] > att["fifo"], att


# --------------------------------------------------------------------------
# chaos: seeded faults, retry-with-backoff, oracle parity
# --------------------------------------------------------------------------

def test_transient_faults_retry_and_heal_token_exact(parts):
    """transient_tries <= max_retries: every injected fault heals on
    retry; all requests complete with tokens identical to the bare
    ReferenceEngine oracle, and the backoff advanced the virtual clock."""
    cfg, model, params = parts
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (4, 7, 5, 9)]

    clk = VirtualClock()
    eng = ServeEngine(model, params, slots=2, max_len=32, clock=clk,
                      max_retries=3, backoff_s=1e-3,
                      chaos=ChaosConfig(seed=1, p_fault=0.4,
                                        transient_tries=2))
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    out = _drain(eng, reqs)
    assert all(r.state == "done" for r in reqs)
    assert eng._chaos.injected["faults"] > 0, "seed injected nothing"
    assert clk.t > 0                       # backoff slept on the clock

    oracle = ReferenceEngine(model, params, slots=2, max_len=32)
    oreqs = [Request(rid=i, prompt=p, max_new_tokens=4)
             for i, p in enumerate(prompts)]
    for r in oreqs:
        oracle.submit(r)
    oracle.run_to_completion(max_steps=500)
    assert out == {r.rid: list(r.out) for r in oreqs}


def test_retries_exhausted_sheds_without_slot_leak(parts):
    """transient_tries > max_retries: the faulty call escalates to
    PermanentFault; its requests end `rejected` (reason device-fault),
    slots are reclaimed, and the rest of the traffic completes."""
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=2, max_len=32, max_retries=1,
                      chaos=ChaosConfig(seed=1, p_fault=0.4,
                                        transient_tries=5))
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4 + i, i), max_new_tokens=3)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    _drain(eng, reqs)
    rejected = [r for r in reqs if r.state == "rejected"]
    assert rejected, "seed 1 must trip at least one permanent fault"
    assert all(r.reason == "device-fault" and not r.done for r in rejected)
    assert any(r.state == "done" for r in reqs), \
        "surviving traffic must still complete"


def test_fault_schedule_is_deterministic():
    """Same (seed, kind, index) -> same fate, independent of retries and
    interleaving; different seeds differ somewhere."""
    def fates(seed, tries=1):
        inj = FaultInjector(ChaosConfig(seed=seed, p_fault=0.5,
                                        transient_tries=tries))
        out = []
        for _ in range(20):
            hits = 0
            while True:
                try:
                    inj.before("decode")
                    break
                except TransientDeviceError:
                    hits += 1
            out.append(hits)
        return out
    a, b = fates(7), fates(7)
    assert a == b and sum(a) > 0
    assert fates(8) != a
    # transient_tries raises the per-site consecutive failure count
    assert sum(fates(7, tries=3)) == 3 * sum(a)


def test_slow_chunk_detector_flags_streaks_not_spikes():
    det = SlowChunkDetector(slow_factor=2.0, patience=2)
    for _ in range(5):
        assert not det.observe(1.0)        # healthy baseline
    assert not det.observe(10.0)           # one spike: strike, no flag
    assert det.observe(10.0)               # second consecutive: flagged
    assert det.flagged_chunks == 1
    # the spikes did not pollute the healthy baseline
    assert det.ewma.value == pytest.approx(1.0)
    assert not det.observe(1.0)            # recovery resets strikes
    assert det.strikes == 0


def test_slow_chunks_shrink_next_chunk(parts):
    """A flagged slow streak halves the next decode chunk (mitigation),
    and the engine still drains with correct terminal states."""
    cfg, model, params = parts
    clk = VirtualClock()
    eng = ServeEngine(model, params, slots=2, max_len=64, decode_chunk=8,
                      clock=clk,
                      chaos=ChaosConfig(seed=2, p_slow=0.8, slow_factor=6.0,
                                        service_seconds=0.01))
    # low patience so the streak flags within this short run
    eng._slow_detect.patience = 1
    reqs = [Request(rid=i, prompt=_prompt(cfg, 5, i), max_new_tokens=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    caps = []
    while eng.queue or any(eng.active):
        eng.step()
        caps.append(eng._chunk_cap)
    assert eng._chaos.injected["slow"] > 0
    assert any(c is not None for c in caps), "detector never flagged"
    assert all(r.done for r in reqs)


def test_ewma_shared_primitive():
    e = Ewma(alpha=0.5)
    assert e.value is None
    assert e.observe(10.0) == 10.0         # first sample seeds
    assert e.observe(0.0) == 5.0
    assert e.observe(5.0) == 5.0


# --------------------------------------------------------------------------
# property test: randomized traffic, bare + chaos engines
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), with_chaos=st.booleans(),
       policy=st.sampled_from(["fifo", "edf", "slo-aware"]))
def test_random_traffic_terminal_states_and_no_leaks(parts, seed,
                                                     with_chaos, policy):
    """Invariants over randomized traffic: admitted lanes never exceed
    slots, running/queued states are consistent at every quantum, every
    request reaches exactly one terminal state, outputs respect budgets,
    and occupancy returns to zero at drain — with and without chaos."""
    cfg, model, params = parts
    rng = np.random.default_rng(seed)
    slots = int(rng.integers(1, 4))
    chaos = ChaosConfig(seed=seed, p_fault=0.2, p_slow=0.2,
                        service_seconds=0.02, transient_tries=1) \
        if with_chaos else None
    eng = ServeEngine(model, params, slots=slots, max_len=32,
                      decode_chunk=4, clock=VirtualClock(),
                      admission=AdmissionConfig(
                          policy=policy,
                          max_queue=int(rng.integers(2, 8))),
                      chaos=chaos)
    reqs = []
    for i in range(int(rng.integers(1, 9))):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(1, 33)),
                                dtype=np.int32),
            # >= 2: a budget-0 lane takes one forced decode step (seed
            # semantics, both engines), so max_new_tokens=1 yields 2 tokens
            max_new_tokens=int(rng.integers(2, 7)),
            deadline_s=float(rng.uniform(0.05, 2.0))
            if rng.random() < 0.5 else None,
            priority=int(rng.integers(0, 3))))
    for r in reqs:
        eng.submit(r)
        eng.step()
    for _ in range(2000):
        live = [r for r in eng.active if r is not None]
        assert len(live) <= slots
        assert all(r.state == "running" for r in live)
        assert all(r.state == "queued" for r in eng.queue)
        if not eng.queue and not live:
            break
        eng.step()
    assert not any(eng.active) and not eng.queue
    states = [r.state for r in reqs]
    assert all(s in TERMINAL_STATES for s in states), states
    for r in reqs:
        assert r.done == (r.state == "done")
        assert len(r.out) <= r.max_new_tokens
    c = eng.admission.counts
    assert c["submitted"] == len(reqs)
    assert c["done"] + c["rejected"] + c["expired"] == len(reqs)


# --------------------------------------------------------------------------
# wave-model prediction plumbing
# --------------------------------------------------------------------------

def test_wave_predictor_monotone_and_bucket_cached(parts):
    cfg, _, _ = parts
    p = WaveLatencyPredictor(cfg)
    small = p.model_seconds(8, 4)
    big = p.model_seconds(8, 32)
    assert 0 < small < big                 # more tokens, more seconds
    p.model_seconds(9, 4)                  # same pow2 bucket as 16? no: 16
    assert len(p._cache) == 3
    p.model_seconds(15, 4)                 # bucket 16 again: cache hit
    assert len(p._cache) == 3


def test_calibration_gates_predictions():
    ctl = AdmissionController(AdmissionConfig(policy="slo-aware"),
                              slots=2, max_len=64)
    assert ctl.predicted_wall_seconds(8, 4) is None    # no predictor
    cfg = reduced(get_arch("granite-8b"))
    ctl = AdmissionController(AdmissionConfig(policy="slo-aware"),
                              slots=2, max_len=64,
                              predictor=WaveLatencyPredictor(cfg))
    assert ctl.predicted_wall_seconds(8, 4) is None    # unwarmed kappa
    ctl.observe_service(model_seconds=1e-6, wall_seconds=1e-3)
    pred = ctl.predicted_wall_seconds(8, 4)
    assert pred is not None and pred > 0


# --------------------------------------------------------------------------
# satellite: trace lowering is time-ordered under priority scheduling
# --------------------------------------------------------------------------

def test_trace_to_gemms_sorts_interleaved_timeline():
    """Priority scheduling can *record* a short-deadline lane's prefill
    after decode chunks that started later; the lowering must follow
    start-time order, not record order."""
    from repro.tenancy.trace import ServeTraceRecorder, trace_to_gemms
    cfg = reduced(get_arch("granite-8b"))

    ordered = ServeTraceRecorder()
    ordered.on_prefill(0, 8, t=0.0)
    ordered.on_decode(1, [8], t=1.0)
    ordered.on_prefill(1, 4, t=2.0)
    ordered.on_decode(2, [9, 4], t=3.0)

    shuffled = ServeTraceRecorder()        # same timeline, recorded badly
    shuffled.on_decode(2, [9, 4], t=3.0)
    shuffled.on_prefill(1, 4, t=2.0)
    shuffled.on_decode(1, [8], t=1.0)
    shuffled.on_prefill(0, 8, t=0.0)

    want = [(g.d1, g.d2, g.d3, g.name)
            for g in trace_to_gemms(ordered, cfg)]
    got = [(g.d1, g.d2, g.d3, g.name)
           for g in trace_to_gemms(shuffled, cfg)]
    assert got == want
    assert want[0][0] == 8                 # prefill-at-8 lowers first


def test_trace_events_without_stamps_keep_record_order():
    """Synthetic traces (no timestamps) must lower exactly as recorded —
    the stamp defaults to the record index, and the sort is stable."""
    from repro.tenancy.trace import ServeTraceRecorder, trace_to_gemms
    cfg = reduced(get_arch("granite-8b"))
    rec = ServeTraceRecorder()
    rec.on_decode(1, [4])
    rec.on_prefill(0, 8)
    gemms = trace_to_gemms(rec, cfg)
    assert gemms[0].d1 == 1                # decode stayed first
    assert rec.num_prefills == 1 and rec.num_decode_steps == 1
    assert rec.phase_tokens("prefill") == 8


# --------------------------------------------------------------------------
# satellite: benchmarks.run --check must exit nonzero on ERROR rows
# --------------------------------------------------------------------------

def _bench_run_module():
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "run.py")
    spec = importlib.util.spec_from_file_location("bench_run_adm", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_exits_nonzero_on_error_rows(monkeypatch, capsys):
    run = _bench_run_module()

    def boom():
        raise RuntimeError("suite blew up")

    monkeypatch.setattr(run, "load_suites", lambda: {"boom": boom})
    monkeypatch.setattr("sys.argv", ["run.py", "--check"])
    monkeypatch.setenv("SOSA_BENCH_CHECK", "1")   # restored at teardown
    with pytest.raises(SystemExit) as ei:
        run.main()
    assert ei.value.code == 1
    out = capsys.readouterr()
    assert "boom/ERROR" in out.out
    assert "CHECK FAIL" in out.err


def test_check_passes_on_clean_suite(monkeypatch, capsys):
    run = _bench_run_module()
    monkeypatch.setattr(run, "load_suites",
                        lambda: {"tiny": lambda: ["tiny/x,1,ok=1"]})
    monkeypatch.setattr("sys.argv", ["run.py", "--check"])
    monkeypatch.setenv("SOSA_BENCH_CHECK", "1")   # restored at teardown
    run.main()                             # no SystemExit
    out = capsys.readouterr()
    assert "tiny/_total" in out.out
    assert "OK" in out.err


# --------------------------------------------------------------------------
# PR 10 bugfixes: κ-calibration skew, cold-start pollution, bounded cache
# --------------------------------------------------------------------------

def test_calibration_observes_actual_tokens_not_budget(parts):
    """κ-skew regression: the calibration sample after retire must price
    the tokens the request ACTUALLY produced (len(out)), not the full
    max_new_tokens budget. An early-EOS request that emits 4 of 16 tokens
    would otherwise divide its wall by a 4x-too-large model_seconds and
    drag κ (and every prediction behind it) down."""
    cfg, model, params = parts

    # discover a token the model actually emits a few steps in
    ref = ReferenceEngine(model, params, slots=1, max_len=64)
    probe = Request(rid=0, prompt=_prompt(cfg, 6, 5), max_new_tokens=16)
    ref.submit(probe)
    ref.run_to_completion(max_steps=200)
    eos = int(probe.out[3])
    if eos in [int(t) for t in probe.out[:3]]:
        eos = int(probe.out[4])

    clk = VirtualClock()
    eng = ServeEngine(model, params, slots=1, max_len=64, decode_chunk=8,
                      eos_id=eos, clock=clk,
                      admission=AdmissionConfig(policy="slo-aware"),
                      chaos=ChaosConfig(seed=0, service_seconds=0.05))
    samples = []
    real = eng.admission.observe_service

    def spy(model_seconds, wall_seconds):
        samples.append((model_seconds, wall_seconds))
        real(model_seconds, wall_seconds)

    eng.admission.observe_service = spy
    # warmup trace with a DIFFERENT prompt (same pow2 bucket, no eos in
    # its early tokens is irrelevant — it may stop early too) to populate
    # the jit caches so the measured request's epoch matches
    warm = Request(rid=1, prompt=_prompt(cfg, 7, 9), max_new_tokens=16)
    eng.submit(warm)
    _drain(eng, [warm])
    kappa_steady = eng.admission._calibration.value
    samples.clear()

    r = Request(rid=2, prompt=_prompt(cfg, 6, 5), max_new_tokens=16)
    eng.submit(r)
    _drain(eng, [r])
    assert r.state == "done" and r.out[-1] == eos
    assert len(r.out) < 16, "probe token must end the request early"
    assert len(samples) == 1, "warm retire must contribute one κ sample"
    p = eng.admission.predictor
    want = p.model_seconds(len(r.prompt), len(r.out))
    not_want = p.model_seconds(len(r.prompt), r.max_new_tokens)
    assert samples[0][0] == pytest.approx(want)
    assert samples[0][0] < not_want, "sample priced at budget, not output"
    # and κ itself stays in the steady-state band instead of cratering
    if kappa_steady is not None:
        assert eng.admission._calibration.value > 0.5 * kappa_steady


def test_cold_start_compile_does_not_pollute_kappa(parts):
    """Cold-start regression: request 1 of a cold engine retires with the
    prefill/decode compiles inside its service wall. That sample must be
    SKIPPED (jit epoch grew during service) — κ stays unwarmed — so a
    deadline-carrying request 2 is not shed on a compile-inflated
    prediction. Request 2's own retire, with stable caches, seeds κ."""
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=1, max_len=64, decode_chunk=8,
                      admission=AdmissionConfig(policy="slo-aware"))
    r1 = Request(rid=1, prompt=_prompt(cfg, 6, 1), max_new_tokens=8)
    import time as _time
    t0 = _time.perf_counter()
    eng.submit(r1)
    _drain(eng, [r1])
    r1_wall = _time.perf_counter() - t0
    assert r1.state == "done"
    # the poisoned sample was dropped: κ is still unwarmed
    assert eng.admission._calibration.value is None
    # request 2: same shapes (warm), deadline far below r1's cold wall —
    # a κ seeded from r1 would predict a miss and shed it at admission
    r2 = Request(rid=2, prompt=_prompt(cfg, 6, 2), max_new_tokens=8,
                 deadline_s=max(0.05, 0.25 * r1_wall))
    eng.submit(r2)
    _drain(eng, [r2])
    assert r2.state == "done", (r2.state, r2.reason)
    assert r2.reason != "shed-predicted-miss"
    # r2 ran on stable caches: ITS sample warms κ
    assert eng.admission._calibration.value is not None


def test_wave_predictor_cache_is_bounded(parts, monkeypatch):
    """Bounded-predictor-cache regression: 10k requests with random
    (prompt, budget) shapes must not grow the memo past cache_cap, and
    the hot (recently used) entries stay resident."""
    cfg, _, _ = parts
    from repro.serve import admission as adm
    monkeypatch.setattr(adm, "request_gemms", lambda *a, **k: None)
    monkeypatch.setattr(adm, "predict_latency_s",
                        lambda *a, **k: 1e-3)
    p = WaveLatencyPredictor(cfg, cache_cap=256)
    rng = np.random.default_rng(0)
    for _ in range(10_000):
        p.model_seconds(int(rng.integers(1, 4096)),
                        int(rng.integers(1, 512)))
        assert len(p._cache) <= p.cache_cap
    # LRU, not FIFO: touching an old key keeps it through later inserts
    p2 = WaveLatencyPredictor(cfg, cache_cap=4)
    for n in (1, 2, 3, 4):
        p2.model_seconds(8, n)
    p2.model_seconds(8, 1)                  # refresh the oldest entry
    p2.model_seconds(8, 5)                  # evicts (8->bucket, 2), not 1
    assert (p2._bucket(8), 1) in p2._cache
    assert (p2._bucket(8), 2) not in p2._cache
