"""Per-architecture smoke tests: reduced same-family config, one forward /
train-loss / prefill+decode step on CPU; asserts shapes + finiteness.
(The FULL configs are exercised only via the dry-run — ShapeDtypeStructs.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, applicable_shapes, get_arch, reduced
from repro.models import Model

B, S = 2, 24


def make_batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab),
    }
    if cfg.encoder_decoder:
        batch["frames"] = jax.random.normal(
            rng, (B, S, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_grad(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # at least one nonzero gradient
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    max_len = S + 8
    cache = model.init_cache(B, max_len, src_len=S)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    tok = jnp.argmax(logits, -1)
    for i in range(3):
        logits, cache = model.decode_step(params, tok, cache, S + i)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = jnp.argmax(logits, -1)


def _assert_logits_close(a, b, atol=0.35):
    """Compare decode vs parallel-forward logits. The two paths take
    structurally different (but mathematically equal) routes through bf16
    arithmetic, so compare shift-invariant log-probabilities; a layout /
    masking bug produces nats-scale divergence, not the <0.1 seen here."""
    la = np.asarray(jax.nn.log_softmax(a.astype(jnp.float32)), np.float32)
    lb = np.asarray(jax.nn.log_softmax(b.astype(jnp.float32)), np.float32)
    np.testing.assert_allclose(la, lb, atol=atol, rtol=0.05)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must match the parallel forward logits."""
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    full_logits, _ = model.forward(params, batch)

    prompt = 8
    pre = {k: (v[:, :prompt] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    cache = model.init_cache(B, S + 4, src_len=S)
    logits, cache = model.prefill(params, pre, cache)
    _assert_logits_close(logits, full_logits[:, prompt - 1])
    for i in range(prompt, min(prompt + 4, S)):
        logits, cache = model.decode_step(
            params, batch["tokens"][:, i], cache, i)
        _assert_logits_close(logits, full_logits[:, i])


def test_shape_skip_rules():
    assert "long_500k" not in applicable_shapes(get_arch("nemotron-4-340b"))
    assert "long_500k" in applicable_shapes(get_arch("mamba2-370m"))
    assert "long_500k" in applicable_shapes(get_arch("hymba-1.5b"))
    assert "long_500k" not in applicable_shapes(get_arch("yi-6b"))
