"""Property tests for the tile_stats-driven Pallas block autotuner
(parallel.autoshard.choose_blocks): randomized GemmSpecs — including the
new transposed (tied-embedding LM head, vocab-scale N) and grouped (MoE
per-expert capacity rows) shapes — must yield candidate blocks whose
kernel-effective clipping divides the padded problem and whose VMEM
working set respects the budget."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.parallel.autoshard import (_VMEM_BUDGET, _rup8, choose_blocks,
                                      choose_blocks_grouped)

CANDIDATES = (128, 256, 512)


def _ops_effective(blocks, m, k, n):
    """The kernel-effective geometry, exactly as ops.systolic_gemm clips
    (min(block, sublane-rounded dim)) before padding to block multiples."""
    bm, bn, bk = blocks
    return min(bm, _rup8(m)), min(bn, _rup8(n)), min(bk, _rup8(k))


def _check_contract(blocks, m, k, n, dtype_bytes, out_bytes):
    assert all(b in CANDIDATES for b in blocks)
    bm_e, bn_e, bk_e = _ops_effective(blocks, m, k, n)
    # the padded problem ops.py builds is an exact multiple of the
    # effective blocks (the kernel asserts this; here it's a property)
    for dim, blk in ((m, bm_e), (k, bk_e), (n, bn_e)):
        padded = -(-dim // blk) * blk
        assert padded % blk == 0
        assert padded - dim < blk          # never pads a full extra block
    # VMEM working set: double-buffered streaming blocks + accumulator +
    # output block (the same accounting choose_blocks scores with)
    vmem = (2 * (bm_e * bk_e + bk_e * bn_e) * dtype_bytes
            + bm_e * bn_e * (4 + out_bytes))
    assert vmem <= _VMEM_BUDGET, (blocks, (m, k, n), vmem)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 8192), k=st.integers(1, 8192),
       n=st.integers(1, 8192),
       dtype_bytes=st.sampled_from([1, 2, 4]),
       out_bytes=st.sampled_from([2, 4]))
def test_choose_blocks_contract(m, k, n, dtype_bytes, out_bytes):
    blocks = choose_blocks(m, k, n, dtype_bytes=dtype_bytes,
                           out_bytes=out_bytes)
    _check_contract(blocks, m, k, n, dtype_bytes, out_bytes)
    # deterministic (and lru-cached) per shape
    assert blocks == choose_blocks(m, k, n, dtype_bytes=dtype_bytes,
                                   out_bytes=out_bytes)


@settings(max_examples=20, deadline=None)
@given(lanes=st.integers(1, 256), d=st.sampled_from([512, 1024, 4096]),
       vocab=st.integers(1000, 300000))
def test_choose_blocks_transposed_lm_head_shapes(lanes, d, vocab):
    """The unembed GEMM (fused decode lanes x d_model x vocab): the
    transposed-weight kernel scores with the same layout-invariant model,
    so the contract must hold at vocab-scale N (up to nemotron's 256k)."""
    blocks = choose_blocks(lanes, d, vocab, dtype_bytes=2, out_bytes=2)
    _check_contract(blocks, lanes, d, vocab, 2, 2)


@settings(max_examples=20, deadline=None)
@given(g=st.integers(1, 160), cap=st.integers(1, 128),
       d=st.sampled_from([64, 1024, 5120]),
       f=st.sampled_from([32, 1536, 10752]))
def test_choose_blocks_grouped_moe_shapes(g, cap, d, f):
    """Grouped (MoE expert) shapes: G pods of (cap x d x f). The group
    axis scales the roofline uniformly, so the grouped entry point must
    agree with the per-group score and satisfy the same contract."""
    blocks = choose_blocks_grouped(g, cap, d, f)
    _check_contract(blocks, cap, d, f, 2, 4)
    assert blocks == choose_blocks(cap, d, f)


def test_choose_blocks_grouped_rejects_zero_groups():
    with pytest.raises(AssertionError):
        choose_blocks_grouped(0, 8, 64, 64)
