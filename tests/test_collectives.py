"""Butterfly collective schedules vs psum, on 8 host devices.

This file (only) forces 8 CPU devices via a subprocess-style env guard:
it must be run in its own pytest process OR before jax initializes. We
guard with xla_force_host_platform_device_count set in conftest fixtures
is NOT possible after init, so we spawn a subprocess.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.parallel.collectives import (
    butterfly_all_reduce, butterfly_all_reduce_expansion2,
    butterfly_reduce_scatter, butterfly_all_gather, ring_all_reduce)
from repro.parallel.compression import compressed_psum

mesh = Mesh(np.array(jax.devices()).reshape(8), ("x",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
ref = jnp.broadcast_to(x.sum(axis=0, keepdims=True), x.shape)

def run(fn):
    f = shard_map(lambda a: fn(a, "x"), mesh=mesh,
                  in_specs=P("x"), out_specs=P("x"), check_rep=False)
    return f(x.reshape(8, 1, 64)).reshape(8, 64)

for name, fn in [("butterfly", butterfly_all_reduce),
                 ("butterfly2", butterfly_all_reduce_expansion2),
                 ("ring", ring_all_reduce)]:
    out = run(lambda a, ax, fn=fn: fn(a[0], ax)[None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5), name
    print(name, "allreduce OK")

# reduce-scatter + all-gather composition == all-reduce
def rs_ag(a, ax):
    rs = butterfly_reduce_scatter(a, ax)
    return butterfly_all_gather(rs, ax)
y = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)  # 64 = 8*8
refy = jnp.broadcast_to(y.sum(axis=0, keepdims=True), y.shape)
f = shard_map(lambda a: rs_ag(a[0, 0], "x")[None], mesh=mesh,
              in_specs=P("x"), out_specs=P("x"), check_rep=False)
out = f(y.reshape(8, 1, 64)).reshape(8, 64)
np.testing.assert_allclose(np.asarray(out), np.asarray(refy),
                           rtol=1e-5, atol=1e-5)
print("rs+ag OK")

# compressed psum: near-exact for one step, unbiased with error feedback
g = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
refg = g.sum(axis=0)
f = shard_map(lambda a: compressed_psum(a[0], "x")[0][None], mesh=mesh,
              in_specs=P("x"), out_specs=P("x"), check_rep=False)
out = f(g.reshape(8, 1, 256))[0]
err = float(jnp.abs(out - refg).max() / jnp.abs(refg).max())
assert err < 0.05, err
print("compressed psum OK rel_err=%.4f" % err)
print("ALL_OK")
"""


@pytest.mark.slow
def test_collectives_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ALL_OK" in out.stdout, out.stdout + "\n" + out.stderr
