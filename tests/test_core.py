"""Core SOSA library: tiling / interconnect / scheduler / simulator —
unit + hypothesis property tests, including the paper-faithfulness gates
from DESIGN.md §7."""

import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (AcceleratorConfig, ArrayConfig, ButterflyRouter,
                        GemmSpec, SliceScheduler, analyze, benes_spec,
                        butterfly_spec, crossbar_spec, make_router,
                        max_pods_under_tdp, merge_workloads, simulate,
                        tile_gemm, tile_workload)
from repro.core.executor import run_gemm_on_sosa
from repro.core.interconnect import butterfly_paths_conflict
from repro.core.workloads import bert, densenet, inception_v3, resnet
from repro.core.dse import table2_rows
from repro.core.simulator import icn_spec_for


# --------------------------------------------------------------------------
# power model (Table 2 'Peak Power' column, DESIGN §7.1)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cols,pods,paper_watts", [
    (512, 512, 1, 113.2), (256, 256, 8, 245.0), (128, 128, 32, 283.1),
    (64, 64, 128, 362.2), (16, 16, 512, 210.6), (32, 32, 256, 260.2),
])
def test_power_model_matches_table2(rows, cols, pods, paper_watts):
    icn = 0.52 if pods > 1 else 0.0
    a = AcceleratorConfig(array=ArrayConfig(rows, cols), num_pods=pods,
                          icn_mw_per_byte=icn)
    assert abs(a.peak_watts - paper_watts) / paper_watts < 0.03


def test_pod_count_selection_matches_paper():
    for (r, pods) in ((16, 512), (32, 256), (64, 128), (128, 32), (256, 8)):
        assert max_pods_under_tdp(ArrayConfig(r, r), 0.52) == pods


def test_peak_throughput_at_tdp():
    a = AcceleratorConfig(array=ArrayConfig(512, 512), num_pods=1,
                          icn_mw_per_byte=0.0)
    assert abs(a.peak_ops_at_tdp / 1e12 - 1853) < 20   # paper: 1853 TOPS


# --------------------------------------------------------------------------
# tiling
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(d1=st.integers(1, 300), d2=st.integers(1, 300), d3=st.integers(1, 300))
def test_tiling_covers_gemm_exactly(d1, d2, d3):
    """Tiles partition the GEMM: MAC counts add up exactly, every chain
    has ceil(d2/r) links, parallel width is ceil(d1/r)*ceil(d3/c)."""
    arr = ArrayConfig(32, 32)
    g = tile_gemm(GemmSpec(d1, d2, d3), arr)
    assert g.total_macs == d1 * d2 * d3
    n_i, n_j, n_l = (math.ceil(d1 / 32), math.ceil(d2 / 32),
                     math.ceil(d3 / 32))
    assert len(g.ops) == n_i * n_j * n_l
    assert g.parallel_frontier() == n_i * n_l
    assert len(g.final_tiles) == n_i * n_l


def test_tiling_partition_rule_default_is_rows():
    arr = ArrayConfig(rows=20, cols=64)
    g = tile_gemm(GemmSpec(100, 64, 64), arr)
    ks = {op.k for op in g.ops}
    assert ks == {20}  # 100 = 5 x 20 exactly


# --------------------------------------------------------------------------
# butterfly routing
# --------------------------------------------------------------------------

def test_butterfly_identity_routes():
    r = ButterflyRouter(8, expansion=1)
    assert r.route([(i, i) for i in range(8)])


def test_butterfly1_blocks_some_permutation_butterfly2_does_not():
    """The paper's Fig 6 argument: expansion 2 recovers permutations a
    standard butterfly cannot route."""
    import itertools
    r1 = ButterflyRouter(8, expansion=1)
    r2 = ButterflyRouter(8, expansion=2)
    blocked = []
    for perm in itertools.islice(itertools.permutations(range(8)), 500):
        pairs = list(enumerate(perm))
        if not r1.route(pairs):
            blocked.append(pairs)
    assert blocked, "butterfly-1 should block some permutations"
    assert all(ButterflyRouter(8, expansion=2).route(p) for p in blocked[:50])


@settings(max_examples=30, deadline=None)
@given(st.permutations(list(range(16))))
def test_benes_crossbar_route_everything(perm):
    for kind in ("benes", "crossbar"):
        assert make_router(kind, 16).route(list(enumerate(perm)))


def test_butterfly_multicast_shares_edges():
    r = ButterflyRouter(8, expansion=1)
    # same source to all destinations = multicast tree, must route
    assert r.route([(3, d) for d in range(8)])


@settings(max_examples=50, deadline=None)
@given(s1=st.integers(0, 15), d1=st.integers(0, 15),
       s2=st.integers(0, 15), d2=st.integers(0, 15))
def test_conflict_is_symmetric(s1, d1, s2, d2):
    assert butterfly_paths_conflict(4, s1, d1, s2, d2) == \
        butterfly_paths_conflict(4, s2, d2, s1, d1)


def test_icn_cost_model_matches_table1():
    for kind, mw in (("butterfly-1", 0.23), ("butterfly-2", 0.52),
                     ("crossbar", 7.36), ("benes", 0.92)):
        got = icn_spec_for(kind, 256).mw_per_byte
        assert abs(got - mw) / mw < 0.30, (kind, got, mw)


# --------------------------------------------------------------------------
# scheduler + executor (numerical proof)
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(d1=st.integers(1, 120), d2=st.integers(1, 120), d3=st.integers(1, 120),
       pods=st.sampled_from([4, 16]))
def test_schedule_executes_exact_gemm(d1, d2, d3, pods):
    rng = np.random.default_rng(d1 * 7 + d2 * 3 + d3)
    x = rng.integers(-100, 100, (d1, d2), dtype=np.int8)
    w = rng.integers(-100, 100, (d2, d3), dtype=np.int8)
    out, sched, graph = run_gemm_on_sosa(x, w, ArrayConfig(32, 32),
                                         num_pods=pods)
    assert np.array_equal(out, x.astype(np.int32) @ w.astype(np.int32))


def test_schedule_respects_dependencies_and_banks():
    arr = ArrayConfig(32, 32)
    graph = tile_workload([GemmSpec(64, 96, 64, gemm_id=0),
                           GemmSpec(64, 64, 64, gemm_id=1,
                                    depends_on=(0,))], arr, num_banks=8)
    sched = SliceScheduler(num_pods=8, array_rows=32, pipeline_latency=4
                           ).schedule(graph)
    slot = sched.assignments
    for op in graph.ops:
        for dep in op.depends_on:
            assert slot[dep][0] < slot[op.op_id][0]
    # single-ported psum banks: within a slice no bank is written twice
    for sl in range(sched.num_slices):
        ops_in = [op for op in graph.ops if slot[op.op_id][0] == sl]
        pbanks = [op.p_bank for op in ops_in]
        assert len(pbanks) == len(set(pbanks))
        pods = [slot[op.op_id][1] for op in ops_in]
        assert len(pods) == len(set(pods))


# --------------------------------------------------------------------------
# simulator: the paper's headline results (trend gates, DESIGN §7)
# --------------------------------------------------------------------------

def test_granularity_32x32_beats_large_arrays():
    from repro.core.workloads import full_suite
    rows = {(p.rows, p.cols): p for p in table2_rows(full_suite())}
    eff32 = rows[(32, 32)].effective_tops_at_tdp
    assert eff32 > rows[(256, 256)].effective_tops_at_tdp
    assert eff32 > rows[(512, 512)].effective_tops_at_tdp
    assert eff32 > rows[(16, 16)].effective_tops_at_tdp
    # utilization ordering: small arrays utilize better
    assert rows[(16, 16)].utilization > rows[(128, 128)].utilization \
        > rows[(512, 512)].utilization


def test_tiling_gain_over_no_partitioning():
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=256)
    wl = bert("medium", 100)
    opt = analyze(wl, accel, k_part=32)
    none = analyze(wl, accel, k_part=10 ** 9)
    assert opt.utilization > 1.5 * none.utilization


def test_multitenancy_gain():
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=256)
    rn, bt = resnet(50, 224), bert("medium", 100)
    r = analyze(rn, accel)
    b = analyze(bt, accel)
    util_seq = (r.total_macs + b.total_macs) / (
        256 * 1024 * (r.total_cycles + b.total_cycles))
    par = analyze(merge_workloads(rn, bt), accel)
    assert par.utilization > 1.1 * util_seq


def test_benes_latency_exposed():
    # the paper's scale: at 256 pods Benes' 2logN-1 (+copy) stages exceed
    # the 32-cycle tile and become exposed (Table 1: ~30 vs ~20 cyc/tile)
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=256)
    wl = bert("mini", 100)
    fast = simulate(wl, accel, interconnect="butterfly-2")
    slow = simulate(wl, accel, interconnect="benes")
    assert slow.cycles_per_tile > 1.2 * fast.cycles_per_tile


def test_butterfly1_busy_pods_lower():
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=64)
    wl = merge_workloads(resnet(50, 128), bert("mini", 100))
    b1 = simulate(wl, accel, interconnect="butterfly-1")
    b2 = simulate(wl, accel, interconnect="butterfly-2")
    assert b2.busy_pods >= b1.busy_pods


def test_workload_traces_sane():
    assert len(resnet(50)) == 54
    assert len(resnet(152)) == 156
    assert len(densenet(121)) == 121
    assert len(inception_v3()) == 95
    # BERT-base: 12 layers x (qkv + 2*12heads attn + o + 2 ffn) = 360
    assert len(bert("base", 100)) == 360
    for g in resnet(50):
        assert g.d1 > 0 and g.d2 > 0 and g.d3 > 0
