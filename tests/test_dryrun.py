"""Dry-run infrastructure: input specs, calibration variants, kv
replication — plus one real 512-device AOT compile (slow)."""

import os
import subprocess
import sys

import pytest

from repro.configs import SHAPES, applicable_shapes, get_arch, list_archs


def test_cell_matrix_is_40():
    """10 archs x 4 shapes = 40 assignment cells; long_500k runs only for
    sub-quadratic archs (the rest are recorded as skipped), none of the
    10 is encoder-only so no decode skips."""
    total = 0
    runnable = 0
    for arch in list_archs():
        cfg = get_arch(arch)
        total += 4
        runnable += len(applicable_shapes(cfg))
    assert total == 40
    # 40 cells - 8 long_500k skips (full-attention archs); mamba2 + hymba
    # keep theirs -> 32 compiled per mesh
    assert runnable == 32


def test_input_specs_shapes():
    os.environ.setdefault("XLA_FLAGS", "")  # no device forcing here
    from repro.launch.dryrun import input_specs
    s = input_specs("yi-6b", "train_4k")
    assert s["tokens"].shape == (256, 4096)
    assert s["labels"].shape == (256, 4096)
    s = input_specs("yi-6b", "decode_32k")
    assert s["tokens"].shape == (128, 1)
    s = input_specs("whisper-small", "prefill_32k")
    assert s["frames"].shape == (32, 32768, 768)
    s = input_specs("llama-3.2-vision-90b", "train_4k")
    assert s["image_embeds"].shape == (256, 1601, 8192)


def test_calibration_cfgs_structure():
    from repro.launch.dryrun import calibration_cfgs
    for arch in list_archs():
        cfg = get_arch(arch)
        c1, c2, extra = calibration_cfgs(cfg)
        assert extra >= 1
        # widths unchanged — only depth scales
        assert c1.d_model == c2.d_model == cfg.d_model
        assert c1.d_ff == cfg.d_ff
        assert c2.n_layers > c1.n_layers


SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("granite-8b", "decode_32k", multi_pod=True, save=False)
assert r["status"] == "ok", r.get("error")
assert r["chips"] == 512
assert r["collective_s"] >= 0 and r["compute_s"] > 0
print("DRYRUN_OK", r["bottleneck"], round(r["hbm_gb_per_chip"], 2))
"""


@pytest.mark.slow
def test_multipod_cell_compiles_512_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "DRYRUN_OK" in out.stdout, out.stdout + out.stderr
