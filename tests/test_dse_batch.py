"""Batched DSE engine vs the scalar path: property-based equivalence.

The batched engine (simulator.analyze_batch + dse.evaluate_grid) must be a
pure vectorization of the original per-point Python loop — same pod-count
selection, same wave model, same averaging. Properties here drive random
(rows, cols, pods, interconnect, k_part) points through both and demand
agreement to float tolerance; the golden test pins the paper's Table-2
ordering (32x32 x 256 pods beats the monolithic 512x512); the speedup test
enforces the whole point of the engine on the Fig-5 grid.
"""

import math
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.arrays import AcceleratorConfig, ArrayConfig
from repro.core.dse import (best_point, evaluate_design,
                            evaluate_design_scalar, sweep, sweep_scalar,
                            table2_rows)
from repro.core.simulator import analyze, analyze_scalar, merge_workloads
from repro.core.tiling import GemmSpec, tile_gemm, tile_stats
from repro.core.workloads import bert, resnet

ICNS = ("butterfly-1", "butterfly-2", "benes", "crossbar", "mesh", "htree")

# small but structurally rich suite: RAW chains, parallel branches,
# attention fan-out, multi-tenant merge
_SUITE = {
    "bert-mini@40": bert("mini", 40),
    "resnet50@64": resnet(50, 64),
    "merged": merge_workloads(resnet(50, 64), bert("mini", 40)),
}


# --------------------------------------------------------------------------
# tile_stats fast path == materializing tiler
# --------------------------------------------------------------------------

# dims bounded so tile_gemm materializes at most ~20k TileOps per example
# (the whole point of tile_stats is to avoid that cost at DSE scale)
@settings(max_examples=30, deadline=None)
@given(d1=st.integers(1, 200), d2=st.integers(1, 300), d3=st.integers(1, 300),
       rows=st.sampled_from([8, 20, 32, 66, 128]),
       cols=st.sampled_from([8, 32, 64, 256]),
       kp=st.sampled_from([None, 7, 32, 10 ** 9]))
def test_tile_stats_matches_tiler(d1, d2, d3, rows, cols, kp):
    arr = ArrayConfig(rows, cols)
    g = GemmSpec(d1, d2, d3)
    graph = tile_gemm(g, arr, k_part=kp)
    stats = tile_stats([g], arr, k_part=kp)
    assert stats.total_tiles == len(graph.ops)
    assert stats.total_macs == graph.total_macs == d1 * d2 * d3
    assert stats.parallel_frontier == graph.parallel_frontier()
    assert int(stats.n_j[0]) == math.ceil(d2 / rows)       # RAW-chain depth
    # k̄: the mean streamed activation rows over materialized tile ops
    mean_k = sum(op.k for op in graph.ops) / len(graph.ops)
    assert stats.k_bar == pytest.approx(mean_k, rel=1e-12)


def test_tile_stats_levels_match_dependencies():
    wl = merge_workloads(resnet(50, 64), bert("mini", 40))
    stats = tile_stats(wl, ArrayConfig(32, 32))
    by_id = {g.gemm_id: i for i, g in enumerate(wl)}
    for i, g in enumerate(wl):
        for pid in g.depends_on:
            assert stats.level[i] > stats.level[by_id[pid]]


# --------------------------------------------------------------------------
# batched analyze == scalar analyze (single-point equivalence)
# --------------------------------------------------------------------------

_SIM_FIELDS = ("total_macs", "utilization", "busy_pods", "cycles_per_tile",
               "effective_tops_at_tdp", "peak_tops_at_tdp", "energy_joules",
               "avg_power_watts", "num_tile_ops")


@settings(max_examples=25, deadline=None)
@given(rows=st.sampled_from([8, 16, 20, 32, 66, 128, 512]),
       cols=st.sampled_from([8, 32, 64, 128, 512]),
       pods=st.sampled_from([1, 2, 8, 64, 256]),
       icn=st.sampled_from(ICNS),
       kp=st.sampled_from([None, 16, 32, 10 ** 9]),
       wl=st.sampled_from(sorted(_SUITE)))
def test_analyze_batched_equals_scalar(rows, cols, pods, icn, kp, wl):
    gemms = _SUITE[wl]
    accel = AcceleratorConfig(array=ArrayConfig(rows, cols), num_pods=pods,
                              icn_mw_per_byte=0.52 if pods > 1 else 0.0)
    a = analyze(gemms, accel, icn, k_part=kp)          # batched, P=1
    b = analyze_scalar(gemms, accel, icn, k_part=kp)   # pure-Python oracle
    for f in _SIM_FIELDS:
        assert getattr(a, f) == pytest.approx(getattr(b, f), rel=1e-9), f
    # int-truncated fields may straddle an exact-integer boundary by 1 ulp
    assert abs(a.total_cycles - b.total_cycles) <= 1
    assert abs(a.num_slices - b.num_slices) <= 1
    assert a.effective_tops_per_watt == pytest.approx(
        b.effective_tops_per_watt, rel=1e-4)


# --------------------------------------------------------------------------
# batched evaluate_design / sweep == scalar path (grid equivalence)
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(rows=st.sampled_from([8, 16, 20, 32, 48, 66, 128, 256]),
       cols=st.sampled_from([8, 16, 32, 64, 256]),
       pods=st.sampled_from([None, 1, 4, 64, 256]),
       icn=st.sampled_from(ICNS))
def test_evaluate_design_batched_equals_scalar(rows, cols, pods, icn):
    a = evaluate_design(rows, cols, _SUITE, icn, num_pods=pods)
    b = evaluate_design_scalar(rows, cols, _SUITE, icn, num_pods=pods)
    assert a.num_pods == b.num_pods            # pod selection is exact
    assert a.peak_tops_at_tdp == pytest.approx(b.peak_tops_at_tdp, rel=1e-12)
    assert a.utilization == pytest.approx(b.utilization, rel=1e-9)
    assert a.effective_tops_at_tdp == pytest.approx(
        b.effective_tops_at_tdp, rel=1e-9)
    assert a.effective_tops_per_watt == pytest.approx(
        b.effective_tops_per_watt, rel=1e-4)


def test_sweep_same_best_point_and_faster():
    """Acceptance gate: on the Fig-5 grid the batched sweep must find the
    same optimum as the scalar loop and be at least 5x faster (it is
    typically 20-30x; 5x leaves headroom for machine noise)."""
    rows = (8, 16, 20, 32, 48, 64, 66, 128, 256)
    cols = (8, 16, 32, 64, 128, 256)
    t0 = time.time()
    pts_b = sweep(_SUITE, rows, cols)
    t_batched = time.time() - t0
    t0 = time.time()
    pts_s = sweep_scalar(_SUITE, rows, cols)
    t_scalar = time.time() - t0

    bb, bs = best_point(pts_b), best_point(pts_s)
    assert (bb.rows, bb.cols, bb.num_pods) == (bs.rows, bs.cols, bs.num_pods)
    for pb, ps in zip(pts_b, pts_s):
        assert (pb.rows, pb.cols, pb.num_pods) == (ps.rows, ps.cols, ps.num_pods)
        assert pb.effective_tops_at_tdp == pytest.approx(
            ps.effective_tops_at_tdp, rel=1e-9)
    assert t_scalar > 5 * t_batched, (t_scalar, t_batched)


# --------------------------------------------------------------------------
# golden regression: Table-2 ordering
# --------------------------------------------------------------------------

def test_table2_golden_ordering():
    """The paper's central claim, pinned: the 32x32 x 256-pod scale-out
    point beats the monolithic 512x512 (and every other Table-2 row) on
    effective throughput @ TDP, and small arrays utilize better."""
    from repro.core.workloads import full_suite
    rows = {(p.rows, p.cols): p for p in table2_rows(full_suite())}
    eff32 = rows[(32, 32)].effective_tops_at_tdp
    assert eff32 > rows[(512, 512)].effective_tops_at_tdp
    assert all(eff32 >= p.effective_tops_at_tdp for p in rows.values())
    assert rows[(16, 16)].utilization > rows[(128, 128)].utilization \
        > rows[(512, 512)].utilization
    # pod counts are the paper's (isopower powers of two, given explicitly)
    assert rows[(32, 32)].num_pods == 256
    assert rows[(512, 512)].num_pods == 1
