"""Flash custom-VJP attention: gradients must match naive autodiff
(the §Perf optimization that removes O(S²) backward residuals)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, naive_attention

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("case", [
    # B, S, Hq, Hkv, Dk, Dv, causal
    (2, 96, 4, 2, 32, 32, True),
    (1, 64, 8, 8, 16, 16, False),
    (2, 80, 6, 2, 32, 48, True),     # Dv != Dk (MLA-style)
    (1, 33, 4, 1, 64, 64, True),     # ragged block edge
])
def test_flash_vjp_grads_match_naive(case):
    B, S, Hq, Hkv, Dk, Dv, causal = case
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, Dk)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, Dk)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, Dv)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((B, S, Hq, Dv)), jnp.float32)

    def f_flash(q, k, v):
        return (chunked_attention(q, k, v, causal=causal, kv_block=32) * w).sum()

    def f_naive(q, k, v):
        return (naive_attention(q, k, v, causal=causal) * w).sum()

    out_f = chunked_attention(q, k, v, causal=causal, kv_block=32)
    out_n = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_n),
                               rtol=2e-3, atol=2e-3)
    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_flash_vjp_no_quadratic_residuals():
    """The saved residuals must be O(S·D): jaxpr of the VJP should contain
    no [.., S, S]-shaped residual between fwd and bwd."""
    B, S, H, D = 1, 256, 2, 16
    q = jnp.zeros((B, S, H, D))
    k = jnp.zeros((B, S, H, D))
    v = jnp.zeros((B, S, H, D))

    def loss(q, k, v):
        return chunked_attention(q, k, v, causal=True, kv_block=64).sum()

    # residuals are the constants captured between fwd and bwd jaxprs
    _, vjp = jax.vjp(loss, q, k, v)
    leaves = jax.tree.leaves(vjp)
    biggest = max((x.size for x in leaves if hasattr(x, "size")), default=0)
    assert biggest <= B * S * H * D * 4, biggest  # q/k/v/out/L-sized only
