"""Cross-pod gradient sync: butterfly / compressed reducers == psum.

Runs in a subprocess with 8 host devices on a (pod=2, data=2, model=2)
mesh — the multi-pod topology at toy scale. Per-pod-distinct payloads are
covered by tests/test_collectives.py at the collectives level; here the
plumbing (flatten -> shard_map over pod -> unflatten, dtype/shape
round-trip, error-feedback carry) is validated with replicated grads:
reduce over a 2-pod axis must return exactly 2x.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.train.grad_sync import make_grad_sync

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
grads = {
    "w1": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
    "w2": {"a": jnp.asarray(rng.standard_normal((5,)), jnp.float32),
           "b": jnp.asarray(rng.standard_normal((3, 3)), jnp.bfloat16)},
}
for impl in ("psum", "butterfly", "butterfly2", "compressed"):
    sync = make_grad_sync(mesh, axis="pod", impl=impl)
    with mesh:
        red, err = jax.jit(lambda g: sync(g))(grads)
    for path, got in [("w1", red["w1"]), ("a", red["w2"]["a"]),
                      ("b", red["w2"]["b"])]:
        want = 2.0 * {"w1": grads["w1"], "a": grads["w2"]["a"],
                      "b": grads["w2"]["b"]}[path]
        tol = 0.05 if impl == "compressed" else 1e-4
        rel = float(jnp.abs(got.astype(jnp.float32)
                            - want.astype(jnp.float32)).max()
                    / jnp.abs(want.astype(jnp.float32)).max())
        assert rel < tol, (impl, path, rel)
    assert (err is not None) == (impl == "compressed")
    # dtype/shape round-trip preserved
    assert red["w2"]["b"].dtype == jnp.bfloat16
    print(impl, "OK")

# no-op on a mesh without the axis
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
sync = make_grad_sync(mesh2, axis="pod", impl="butterfly")
red, err = sync(grads)
assert err is None
np.testing.assert_array_equal(np.asarray(red["w1"]),
                              np.asarray(grads["w1"]))
print("ALL_OK")
"""


@pytest.mark.slow
def test_grad_sync_reducers():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ALL_OK" in out.stdout, out.stdout + "\n" + out.stderr
