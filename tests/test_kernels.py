"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp ref.py oracles (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.systolic_gemm.ops import (fused_lane_gemm,
                                             fused_lane_gemm_t, grouped_gemm,
                                             systolic_gemm, systolic_gemm_t)
from repro.kernels.systolic_gemm.ref import (systolic_gemm_ref,
                                             systolic_gemm_t_ref)

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------------
# systolic GEMM
# --------------------------------------------------------------------------

GEMM_SHAPES = [(64, 64, 64), (128, 256, 128), (100, 130, 70), (1, 1, 1),
               (33, 257, 129), (8, 1024, 8), (512, 64, 512)]


@pytest.mark.parametrize("shape", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", ["int8", "bfloat16", "float32"])
def test_systolic_gemm_shapes(shape, dtype):
    M, K, N = shape
    if dtype == "int8":
        x = jnp.asarray(RNG.integers(-100, 100, (M, K)), jnp.int8)
        w = jnp.asarray(RNG.integers(-100, 100, (K, N)), jnp.int8)
        tol = 1e-5
    else:
        x = jnp.asarray(RNG.standard_normal((M, K)), dtype)
        w = jnp.asarray(RNG.standard_normal((K, N)), dtype)
        tol = 2e-2 if dtype == "bfloat16" else 1e-5
    out = systolic_gemm(x, w, interpret=True)
    ref = systolic_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu", "relu2"])
def test_systolic_gemm_epilogue(act):
    """The fused post-processor epilogue (scale + bias + activation)."""
    M, K, N = 96, 160, 72
    x = jnp.asarray(RNG.integers(-64, 64, (M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-64, 64, (K, N)), jnp.int8)
    s = jnp.asarray(RNG.random(N) * 0.1, jnp.float32)
    b = jnp.asarray(RNG.standard_normal(N), jnp.float32)
    out = systolic_gemm(x, w, s, b, activation=act, interpret=True)
    ref = systolic_gemm_ref(x, w, s, b, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 64, 256), (32, 128, 32)])
def test_systolic_gemm_block_invariance(blocks):
    """SOSA pillar 1 as a property: the result must be invariant to the pod
    (block) granularity — only throughput/Watt changes, never the math."""
    bm, bn, bk = blocks
    M, K, N = 160, 192, 136
    x = jnp.asarray(RNG.integers(-50, 50, (M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-50, 50, (K, N)), jnp.int8)
    out = systolic_gemm(x, w, block_m=bm, block_n=bn, block_k=bk,
                        interpret=True)
    ref = systolic_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 80), n=st.integers(1, 80))
def test_systolic_gemm_property(m, k, n):
    x = jnp.asarray(RNG.integers(-8, 8, (m, k)), jnp.int8)
    w = jnp.asarray(RNG.integers(-8, 8, (k, n)), jnp.int8)
    out = systolic_gemm(x, w, block_m=32, block_n=32, block_k=32,
                        interpret=True)
    ref = systolic_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# --------------------------------------------------------------------------
# grouped / fused-lane GEMM variants
# --------------------------------------------------------------------------

GROUPED_SHAPES = [(2, 32, 40, 24), (3, 64, 64, 64), (1, 5, 130, 17),
                  (4, 33, 17, 65)]


@pytest.mark.parametrize("shape", GROUPED_SHAPES)
@pytest.mark.parametrize("dtype", ["int8", "float32"])
def test_grouped_gemm_matches_per_group_ref(shape, dtype):
    """G independent GEMMs in one launch == per-group oracle."""
    G, M, K, N = shape
    if dtype == "int8":
        x = jnp.asarray(RNG.integers(-50, 50, (G, M, K)), jnp.int8)
        w = jnp.asarray(RNG.integers(-50, 50, (G, K, N)), jnp.int8)
    else:
        x = jnp.asarray(RNG.standard_normal((G, M, K)), jnp.float32)
        w = jnp.asarray(RNG.standard_normal((G, K, N)), jnp.float32)
    out = grouped_gemm(x, w, interpret=True)
    ref = jnp.stack([systolic_gemm_ref(x[g], w[g]) for g in range(G)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_grouped_gemm_per_group_epilogue():
    """Per-group dequant scale + bias + activation (the SIMD
    post-processor, one per pod group)."""
    G, M, K, N = 3, 24, 48, 40
    x = jnp.asarray(RNG.integers(-40, 40, (G, M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-40, 40, (G, K, N)), jnp.int8)
    s = jnp.asarray(RNG.random((G, N)) * 0.1, jnp.float32)
    b = jnp.asarray(RNG.standard_normal((G, N)), jnp.float32)
    out = grouped_gemm(x, w, s, b, activation="silu", interpret=True)
    ref = jnp.stack([systolic_gemm_ref(x[g], w[g], s[g], b[g],
                                       activation="silu")
                     for g in range(G)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_fused_lane_gemm_collapses_leading_axes():
    """[B, S, K] @ [K, N] runs as one (B*S, K) GEMM — the fused decode-lane
    shape — and restores the leading axes."""
    x = jnp.asarray(RNG.standard_normal((4, 3, 32)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((32, 24)), jnp.float32)
    out = fused_lane_gemm(x, w, interpret=True)
    assert out.shape == (4, 3, 24)
    ref = jnp.einsum("bsk,kn->bsn", x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# grouped GEMM edge cases (the shapes MoE capacity-bucket dispatch hits)
# --------------------------------------------------------------------------

def test_grouped_gemm_empty_group_stays_zero():
    """An expert that received no tokens is an all-zero group: its output
    must be exactly zero (no epilogue bleed), neighbours unaffected."""
    G, M, K, N = 3, 16, 32, 24
    x = jnp.asarray(RNG.standard_normal((G, M, K)), jnp.float32)
    x = x.at[1].set(0.0)                       # expert 1: empty bucket
    w = jnp.asarray(RNG.standard_normal((G, K, N)), jnp.float32)
    out = grouped_gemm(x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    for g in (0, 2):
        np.testing.assert_allclose(np.asarray(out[g]),
                                   np.asarray(systolic_gemm_ref(x[g], w[g])),
                                   rtol=1e-5, atol=1e-5)


def test_grouped_gemm_ragged_fill():
    """Capacity buckets are ragged: each group has a different number of
    real rows, the rest zero-padded. Real rows must match the per-group
    oracle, padded rows stay exactly zero (rows are independent in a
    GEMM — the invariant the MoE scatter dispatch relies on)."""
    G, M, K, N = 4, 12, 20, 16
    fills = [12, 5, 1, 0]
    x = jnp.asarray(RNG.standard_normal((G, M, K)), jnp.float32)
    mask = (np.arange(M)[None, :] < np.asarray(fills)[:, None])
    x = x * jnp.asarray(mask[..., None], jnp.float32)
    w = jnp.asarray(RNG.standard_normal((G, K, N)), jnp.float32)
    out = np.asarray(grouped_gemm(x, w, interpret=True))
    ref = np.stack([np.asarray(systolic_gemm_ref(x[g], w[g]))
                    for g in range(G)])
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    for g, f in enumerate(fills):
        np.testing.assert_array_equal(out[g, f:], 0.0)


def test_grouped_gemm_single_group_degenerates_to_gemm():
    """G == 1 (single-expert model) must equal the plain pod GEMM."""
    M, K, N = 40, 56, 33
    x = jnp.asarray(RNG.integers(-40, 40, (1, M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-40, 40, (1, K, N)), jnp.int8)
    out = grouped_gemm(x, w, interpret=True)
    ref = systolic_gemm(x[0], w[0], interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref),
                               rtol=1e-6)


# --------------------------------------------------------------------------
# transposed-weight GEMM (the tied-embedding LM head)
# --------------------------------------------------------------------------

GEMM_T_SHAPES = [(64, 64, 64), (100, 130, 70), (1, 16, 8), (33, 257, 129),
                 (8, 64, 500)]


@pytest.mark.parametrize("shape", GEMM_T_SHAPES)
@pytest.mark.parametrize("dtype", ["int8", "bfloat16", "float32"])
def test_systolic_gemm_t_shapes(shape, dtype):
    """x [M,K] @ w[N,K]^T == oracle, across dtypes and ragged dims."""
    M, K, N = shape
    if dtype == "int8":
        x = jnp.asarray(RNG.integers(-100, 100, (M, K)), jnp.int8)
        w = jnp.asarray(RNG.integers(-100, 100, (N, K)), jnp.int8)
        tol = 1e-5
    else:
        x = jnp.asarray(RNG.standard_normal((M, K)), dtype)
        w = jnp.asarray(RNG.standard_normal((N, K)), dtype)
        tol = 2e-2 if dtype == "bfloat16" else 1e-5
    out = systolic_gemm_t(x, w, interpret=True)
    ref = systolic_gemm_t_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("act", [None, "silu", "relu2"])
def test_systolic_gemm_t_epilogue(act):
    M, K, N = 48, 80, 56
    x = jnp.asarray(RNG.integers(-64, 64, (M, K)), jnp.int8)
    w = jnp.asarray(RNG.integers(-64, 64, (N, K)), jnp.int8)
    s = jnp.asarray(RNG.random(N) * 0.1, jnp.float32)
    b = jnp.asarray(RNG.standard_normal(N), jnp.float32)
    out = systolic_gemm_t(x, w, s, b, activation=act, interpret=True)
    ref = systolic_gemm_t_ref(x, w, s, b, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_fused_lane_gemm_t_is_the_tied_unembed():
    """[B, S, d] against the stored [vocab, d] token table == x @ tok.T —
    the tied-embedding LM head, no transpose copy."""
    vocab, d = 96, 32
    x = jnp.asarray(RNG.standard_normal((2, 5, d)), jnp.float32)
    tok = jnp.asarray(RNG.standard_normal((vocab, d)), jnp.float32)
    out = fused_lane_gemm_t(x, tok, interpret=True)
    assert out.shape == (2, 5, vocab)
    ref = jnp.einsum("bsd,vd->bsv", x, tok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_unembed_pallas_matches_einsum_tied_and_untied():
    """models.layers.unembed(use_pallas=True): both embedding layouts run
    the pod kernel and match the einsum oracle."""
    from repro.models.layers import embed_schema, init_from_schema, unembed
    x = jnp.asarray(RNG.standard_normal((2, 3, 16)), jnp.float32)
    for tie in (True, False):
        p = init_from_schema(jax.random.PRNGKey(0),
                             embed_schema(50, 16, tie))
        p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
        ref = unembed(p, x)
        out = unembed(p, x, use_pallas=True)
        assert out.dtype == ref.dtype
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

ATTN_CASES = [
    # B, Sq, Hq, Hkv, D, causal, window
    (2, 64, 4, 2, 32, True, None),
    (1, 100, 8, 8, 16, True, None),
    (2, 33, 4, 1, 64, False, None),
    (1, 128, 5, 5, 32, True, 48),
    (1, 256, 16, 2, 64, True, None),
    (1, 80, 6, 3, 128, True, 16),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, S, Hq, Hkv, D, causal, win = case
    q = jnp.asarray(RNG.standard_normal((B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_chunked_jax():
    """Kernel == the pure-JAX chunked production path (same blocking)."""
    from repro.models.attention import chunked_attention
    q = jnp.asarray(RNG.standard_normal((2, 96, 8, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 96, 4, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 96, 4, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                        interpret=True)
    b = chunked_attention(q, k, v, causal=True, kv_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 70), d=st.sampled_from([8, 16, 32]),
       hq=st.sampled_from([1, 2, 4]), causal=st.booleans())
def test_flash_attention_property(s, d, hq, causal):
    q = jnp.asarray(RNG.standard_normal((1, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, s, 1, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, s, 1, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


# --------------------------------------------------------------------------
# SSD
# --------------------------------------------------------------------------

SSD_CASES = [(2, 64, 4, 16, 1, 32, 16), (1, 100, 2, 8, 2, 16, 32),
             (1, 32, 4, 16, 4, 8, 32), (2, 48, 8, 32, 1, 64, 16)]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_sweep(case):
    b, S, H, P, G, N, chunk = case
    x = jnp.asarray(RNG.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, S, H)) * 0.5 + 0.1, jnp.float32)
    A = jnp.asarray(-RNG.random(H) - 0.1, jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, S, G, N)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, S, G, N)), jnp.float32)
    D = jnp.asarray(RNG.random(H), jnp.float32)
    y, h = ssd(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    rep = H // G
    yr, hr = ssd_ref(x, dt, A, jnp.repeat(B, rep, 2), jnp.repeat(C, rep, 2),
                     D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_invariance():
    """Chunk size is a tiling knob (SOSA pillar 3): must not change the
    result."""
    b, S, H, P, N = 1, 96, 2, 16, 32
    x = jnp.asarray(RNG.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, S, H)) * 0.3 + 0.1, jnp.float32)
    A = jnp.asarray(-RNG.random(H) - 0.1, jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, S, 1, N)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, S, 1, N)), jnp.float32)
    D = jnp.asarray(RNG.random(H), jnp.float32)
    outs = [np.asarray(ssd(x, dt, A, B, C, D, chunk=c, interpret=True)[0])
            for c in (16, 32, 96)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=5e-4, atol=5e-4)


def test_ssd_decode_consistency():
    """Sequential decode steps == chunked prefill (the serving invariant)."""
    from repro.models.ssm import ssd_decode_step
    b, S, H, P, N = 1, 24, 2, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, S, H)) * 0.3 + 0.1, jnp.float32)
    A = jnp.asarray(-RNG.random(H) - 0.1, jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, S, H, N)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, S, H, N)), jnp.float32)
    D = jnp.asarray(RNG.random(H), jnp.float32)
    y_chunk, h_chunk = ssd_ref(x, dt, A, B, C, D, chunk=8)
    h = jnp.zeros((b, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y, h = ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], D, h)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chunk),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_chunk),
                               rtol=1e-3, atol=1e-3)
