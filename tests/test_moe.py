"""MoE: grouped dispatch correctness + sort/onehot equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch, reduced
from repro.configs.base import MoEConfig
from repro.models.layers import init_from_schema
from repro.models.moe import _group_shape, apply_moe, moe_schema


def _setup(E=8, K=2, group=16, dispatch="onehot", cf=8.0):
    cfg = dataclasses.replace(
        reduced(get_arch("dbrx-132b")),
        moe=MoEConfig(num_experts=E, top_k=K, d_ff_expert=32,
                      group_size=group, dispatch=dispatch,
                      capacity_factor=cf))
    p = init_from_schema(jax.random.PRNGKey(0), moe_schema(cfg))
    return cfg, p


def test_group_shape_divides():
    for n, gs in [(1024, 128), (100, 128), (7, 3), (4096 * 256, 128)]:
        G, per = _group_shape(n, gs)
        assert G * per == n


def test_moe_no_drop_equals_dense_mixture():
    """With huge capacity nothing drops: output == sum_k gate_k * FFN_ek(x)."""
    cfg, p = _setup(cf=100.0)
    m = cfg.moe
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out = apply_moe(p, x, cfg)

    # dense reference: run every expert on every token, weight by gates
    xt = x.reshape(1, 32, cfg.d_model)
    from repro.models.moe import _route
    gate, eidx, pos, keep, cap = _route(p, x.reshape(*_gshape(cfg, 32)), m)
    act = jax.nn.silu
    h = jnp.einsum("btd,edf->btef", x.reshape(2, 16, -1), p["up"])
    g = act(jnp.einsum("btd,edf->btef", x.reshape(2, 16, -1), p["gate"]))
    ye = jnp.einsum("btef,efd->bted", h * g, p["down"])   # every expert
    G, n = _group_shape(32, m.group_size)
    gate_r = gate.reshape(2, 16, m.top_k)
    eidx_r = eidx.reshape(2, 16, m.top_k)
    ref = jnp.zeros_like(x)
    for k in range(m.top_k):
        sel = jnp.take_along_axis(ye, eidx_r[..., k][..., None, None],
                                  axis=2)[..., 0, :]
        ref = ref + gate_r[..., k][..., None] * sel
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def _gshape(cfg, n_tokens):
    G, n = _group_shape(n_tokens, cfg.moe.group_size)
    return G, n, cfg.d_model


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), E=st.sampled_from([4, 8, 16]),
       K=st.sampled_from([1, 2, 4]), cf=st.sampled_from([1.0, 2.0, 100.0]))
def test_sort_dispatch_equals_onehot(seed, E, K, cf):
    """The §Perf sort dispatch must be bit-compatible with the GShard
    reference, including capacity drops."""
    cfg_a, p = _setup(E=E, K=K, dispatch="onehot", cf=cf)
    cfg_b = dataclasses.replace(
        cfg_a, moe=dataclasses.replace(cfg_a.moe, dispatch="sort"))
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg_a.d_model),
                          jnp.float32)
    a = apply_moe(p, x, cfg_a)
    b = apply_moe(p, x, cfg_b)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_capacity_drops_are_real():
    cfg, p = _setup(E=4, K=4, cf=0.25)   # force heavy dropping
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    out = apply_moe(p, x, cfg)
    assert bool(jnp.isfinite(out).all())


# --------------------------------------------------------------------------
# grouped pod-GEMM dispatch (the use_pallas serving hot path)
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100), E=st.sampled_from([4, 8]),
       cf=st.sampled_from([1.0, 2.0, 100.0]))
def test_grouped_pod_dispatch_matches_onehot(seed, E, cf):
    """apply_moe(use_pallas=True) — capacity-bucketed scatter dispatch +
    grouped systolic GEMM experts — must match the GShard one-hot einsum
    oracle, including under capacity drops."""
    cfg, p = _setup(E=E, K=2, dispatch="onehot", cf=cf)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model),
                          jnp.float32)
    a = apply_moe(p, x, cfg)
    b = apply_moe(p, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_grouped_pod_dispatch_with_shared_experts():
    """DeepSeek-style shared experts ride the pod GEMM too."""
    cfg = reduced(get_arch("deepseek-v2-236b"))
    p = init_from_schema(jax.random.PRNGKey(0), moe_schema(cfg))
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.float32)
    a = apply_moe(p, x, cfg)
    b = apply_moe(p, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_pod_dispatch_runs_grouped_gemm_not_einsum(monkeypatch):
    """The hot path must actually hit the grouped kernel: three launches
    (up / gate / down), and the one-hot dispatch einsums must not run
    (the einsum path would call _experts instead)."""
    import repro.kernels.systolic_gemm.ops as gops
    import repro.models.moe as moe_mod
    calls = {"grouped": 0, "einsum_experts": 0}
    real = gops.grouped_gemm
    monkeypatch.setattr(
        gops, "grouped_gemm",
        lambda *a, **k: (calls.__setitem__("grouped", calls["grouped"] + 1),
                         real(*a, **k))[1])
    real_experts = moe_mod._experts
    monkeypatch.setattr(
        moe_mod, "_experts",
        lambda *a, **k: (calls.__setitem__("einsum_experts",
                                           calls["einsum_experts"] + 1),
                         real_experts(*a, **k))[1])
    cfg, p = _setup(E=4, K=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model),
                          jnp.float32)
    apply_moe(p, x, cfg, use_pallas=True)
    assert calls["grouped"] == 3
    assert calls["einsum_experts"] == 0
