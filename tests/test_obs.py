"""Observability layer tests: metrics registry semantics, engine
telemetry population, Chrome trace-event export, kernel autotune metrics,
and the model-vs-measured drift gate (the wave model's predicted
utilization over the slice-accurate scheduler's measured utilization on
the engine's actually-recorded timeline must stay inside the calibrated
parity band of tests/test_simulator.py)."""

import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.model import Model
from repro.obs.drift import drift_report, effective_tops_summary
from repro.obs.export import Span, to_chrome_trace, write_chrome_trace
from repro.obs.metrics import MetricsRegistry, percentile, registry
from repro.serve.engine import Request, ServeEngine
from repro.tenancy.trace import ServeTraceRecorder

# the wave model may be optimistic by up to the bert-family calibrated
# ceiling (tests/test_simulator.py PARITY_CASES) and must never predict
# below the slice-accurate scheduler by more than the resnet floor
DRIFT_BAND = (0.8, 1.55)


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_counter_gauge_series_and_labels():
    reg = MetricsRegistry()
    reg.counter("hits", path="bucketed").inc()
    reg.counter("hits", path="bucketed").inc(2)
    reg.counter("hits", path="exact").inc()
    reg.gauge("depth").set(7)
    assert reg.value("hits", path="bucketed") == 3
    assert reg.value("hits", path="exact") == 1
    assert reg.value("depth") == 7
    assert reg.value("never_written") is None
    # same name, different labels -> distinct series, both findable
    assert set(reg.find("hits")) == {"hits{path=bucketed}",
                                     "hits{path=exact}"}
    with pytest.raises(ValueError):
        reg.counter("hits", path="exact").inc(-1)


def test_histogram_percentiles_and_decimation():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.record(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1 and s["max"] == 100
    assert s["p50"] == pytest.approx(np.percentile(range(1, 101), 50))
    assert s["p99"] == pytest.approx(np.percentile(range(1, 101), 99))
    # bounded buffer: exact count/total survive decimation
    from repro.obs.metrics import Histogram
    small = Histogram(max_samples=8)
    for v in range(1000):
        small.record(float(v))
    assert small.count == 1000
    assert len(small._samples) <= 8
    assert small.max == 999.0
    # n-at-once recording (a chunk charging every delivered token)
    hh = Histogram()
    hh.record(5.0, n=10)
    assert hh.count == 10 and hh.total == 50.0


def test_percentile_matches_numpy():
    vals = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6]
    for q in (0, 10, 50, 90, 99, 100):
        assert percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)))
    assert math.isnan(percentile([], 50))


def test_snapshot_is_json_round_trippable():
    reg = MetricsRegistry()
    reg.counter("c", a="1").inc(5)
    reg.gauge("g").set(2.5)
    reg.histogram("h").record(1.0)
    snap = json.loads(reg.dumps())
    assert snap["counters"] == {"c{a=1}": 5.0}
    assert snap["gauges"] == {"g": 2.5}
    assert snap["histograms"]["h"]["count"] == 1
    assert len(reg) == 3
    reg.clear()
    assert len(reg) == 0


# --------------------------------------------------------------------------
# engine telemetry + trace export
# --------------------------------------------------------------------------

def _served_engine(metrics=None, tracer=None, lengths=(5, 9, 17), max_new=4):
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=2, max_len=32,
                      metrics=metrics, tracer=tracer)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, n,
                                               dtype=np.int32),
                    max_new_tokens=max_new)
            for i, n in enumerate(lengths)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=200)
    assert all(r.done for r in reqs)
    return cfg, eng, reqs


def test_engine_populates_serving_metrics():
    reg = MetricsRegistry()
    cfg, eng, reqs = _served_engine(metrics=reg)
    snap = reg.snapshot()
    assert reg.value("serve.prefill.tokens") == 5 + 9 + 17
    assert reg.value("serve.prefill.calls", path="bucketed") >= 1
    # every request's decode tokens were counted (prefill token excluded)
    decoded = sum(len(r.out) - 1 for r in reqs)
    assert reg.value("serve.decode.tokens") == decoded
    assert reg.value("serve.decode.chunks") >= 1
    assert reg.value("serve.queue_depth") == 0          # drained
    assert 0 < snap["gauges"]["serve.slot_occupancy"] <= 1.0
    assert snap["histograms"]["serve.decode.token_wait_us"]["count"] \
        == decoded
    assert snap["histograms"]["serve.decode.chunk_len"]["count"] \
        == reg.value("serve.decode.chunks")
    assert reg.value("serve.decode.tok_s") > 0
    assert reg.value("serve.prefill.seconds") > 0
    assert reg.value("serve.decode.seconds") > 0


def test_engine_emits_spans_and_valid_chrome_trace(tmp_path):
    rec = ServeTraceRecorder()
    _, eng, _ = _served_engine(tracer=rec)
    assert rec.spans, "engine emitted no spans"
    cats = {s.cat for s in rec.spans}
    assert cats == {"prefill", "decode"}
    assert rec.phase_seconds("prefill") > 0
    assert rec.phase_seconds("decode") > 0
    # decode spans carry the device-side accumulators in their args
    dspans = [s for s in rec.spans if s.cat == "decode"]
    assert sum(s.args["tokens"] for s in dspans) \
        == rec.phase_tokens("decode")

    out = tmp_path / "trace.json"
    n = write_chrome_trace(str(out), rec.spans)
    assert n == len(rec.spans)
    doc = json.loads(out.read_text())
    assert isinstance(doc["traceEvents"], list)
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    complete = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["args"]["name"] for e in meta} >= {"sosa-serve", "prefill",
                                                "decode"}
    assert len(complete) == len(rec.spans)
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0          # rebased to t=0
        assert {"name", "cat", "pid", "tid", "args"} <= set(e)
    # phase tracks: distinct tid per category
    tids = {e["cat"]: e["tid"] for e in complete}
    assert tids["prefill"] != tids["decode"]
    # chronological within the engine's step-locked order
    ts = [e["ts"] for e in complete]
    assert min(ts) == 0.0


def test_to_chrome_trace_empty_spans():
    doc = to_chrome_trace([])
    assert doc["traceEvents"][0]["args"]["name"] == "sosa-serve"
    assert all(e["ph"] == "M" for e in doc["traceEvents"])


def test_span_end_property():
    s = Span(name="x", ts=1.5, dur=0.25)
    assert s.end == 1.75


# --------------------------------------------------------------------------
# kernel autotune metrics
# --------------------------------------------------------------------------

def test_choose_blocks_records_autotune_metrics():
    from repro.parallel.autoshard import choose_blocks, tile_utilization
    reg = registry()
    shape = (7777, 4096, 4096)                   # unique -> guaranteed miss
    choose_blocks.cache_clear()
    before_miss = reg.value("autotune.cache", result="miss") or 0
    before_hit = reg.value("autotune.cache", result="hit") or 0
    blocks = choose_blocks(*shape)
    assert (reg.value("autotune.cache", result="miss") or 0) \
        == before_miss + 1
    choose_blocks(*shape)
    assert (reg.value("autotune.cache", result="hit") or 0) \
        == before_hit + 1
    util = reg.value("autotune.tile_util",
                     shape="x".join(str(d) for d in shape))
    assert util is not None
    assert 0 < util <= 1.0
    assert util == pytest.approx(tile_utilization(*shape, blocks=blocks))


def test_tile_utilization_penalizes_padding():
    from repro.parallel.autoshard import tile_utilization
    # aligned shape wastes nothing; a ragged M pays padded-MAC overhead
    full = tile_utilization(4096, 4096, 4096, blocks=(256, 256, 256))
    ragged = tile_utilization(100, 4096, 4096, blocks=(256, 256, 256))
    assert full == pytest.approx(1.0)
    assert ragged < full


# --------------------------------------------------------------------------
# drift + effective TOPS (the acceptance gates)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    reg = MetricsRegistry()
    rec = ServeTraceRecorder()
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=4, max_len=64,
                      metrics=reg, tracer=rec)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, n,
                                               dtype=np.int32),
                    max_new_tokens=6)
            for i, n in enumerate((5, 9, 17, 12, 33, 7))]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=300)
    assert all(r.done for r in reqs)
    return cfg, reg, rec


def test_drift_rows_per_phase_inside_calibrated_band(traced_run):
    """The tentpole gate: one drift row per serving phase, and predicted
    (wave model) utilization over measured (slice-accurate) utilization on
    the engine's real recorded timeline stays inside the calibrated
    parity band."""
    cfg, reg, rec = traced_run
    rows = drift_report(rec, cfg, metrics=reg, max_events_per_phase=16)
    assert {r.phase for r in rows} == {"prefill", "decode"}
    lo, hi = DRIFT_BAND
    for r in rows:
        assert r.events > 0 and r.gemms > 0
        assert 0 < r.measured_utilization <= 1.0
        assert 0 < r.predicted_utilization <= 1.0
        assert lo <= r.drift <= hi, \
            f"{r.phase}: drift {r.drift:.3f} outside [{lo}, {hi}]"
        assert r.predicted_cycles > 0 and r.measured_cycles > 0
        # the gauge mirror the benchmark suite reads
        assert reg.value("obs.drift", phase=r.phase) \
            == pytest.approx(r.drift)
        assert reg.value("obs.predicted_util", phase=r.phase) \
            == pytest.approx(r.predicted_utilization)


def test_drift_skips_unrecorded_phases():
    rec = ServeTraceRecorder()
    rec.on_prefill(0, 8)
    cfg = reduced(get_arch("granite-8b"))
    rows = drift_report(rec, cfg, metrics=MetricsRegistry())
    assert [r.phase for r in rows] == ["prefill"]


def test_effective_tops_gauge_live(traced_run):
    """Effective TOPS as the paper defines it — measured throughput x
    utilization — computed from live telemetry and recorded as a gauge."""
    cfg, reg, rec = traced_run
    kreg = MetricsRegistry()
    from repro.parallel.autoshard import choose_blocks as cb, \
        tile_utilization
    blocks = cb(64, cfg.d_model, cfg.d_ff)
    kreg.gauge("autotune.tile_util",
               shape=f"64x{cfg.d_model}x{cfg.d_ff}").set(
        tile_utilization(64, cfg.d_model, cfg.d_ff, blocks))
    rows = effective_tops_summary(rec, cfg, reg, kernel_metrics=kreg)
    assert {r.phase for r in rows} == {"prefill", "decode"}
    for r in rows:
        assert r.tokens == rec.phase_tokens(r.phase)
        assert r.seconds == pytest.approx(
            reg.value(f"serve.{r.phase}.seconds"))
        assert r.tok_s > 0 and r.macs_per_token > 0
        assert 0 < r.tile_utilization <= 1.0
        # effective = measured x utilization, by construction and as gauge
        assert r.effective_tops == pytest.approx(
            r.measured_tops * r.tile_utilization)
        assert reg.value("obs.effective_tops", phase=r.phase) \
            == pytest.approx(r.effective_tops)


def test_effective_tops_unit_utilization_without_kernel_gauges(traced_run):
    cfg, reg, rec = traced_run
    rows = effective_tops_summary(rec, cfg, reg,
                                  kernel_metrics=MetricsRegistry())
    for r in rows:
        assert r.tile_utilization == 1.0
        assert r.effective_tops == pytest.approx(r.measured_tops)
