"""Paged KV cache + in-chunk lane recycling (serve/paging.py,
models/attention.PagedKVCache, ServeEngine(paged=True)).

The contract under test:
  * paged serving is token-exact vs the dense ReferenceEngine oracle
    (the gathered per-lane view is position-ordered, so attention math is
    bit-identical) — here for dense traffic, in test_serve_matrix.py for
    every bucketed family;
  * device KV bytes scale with LIVE context: mapped bytes stay <= 1.25x
    sum-of-true-lengths x per-token bytes at steady state, vs the dense
    slots x max_len reservation;
  * a lane that dies mid-chunk hands its slot (and pages) to a queued
    request at that same chunk sync — the successor is running before the
    caller sees the next quantum, no intervening idle chunk;
  * admission is page-driven: a request bigger than the whole pool is
    rejected ``pages-exhausted`` at submit; an oversubscribed pool
    (kv_pages < slots x max_len / page_size) queues on pages and still
    completes everything;
  * page-pool invariants hold under randomized traffic with chaos armed —
    {free} + {owned} exactly partition the pool at every quantum, pages
    allocated == pages freed at drain, and no fault path leaks a page.
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch, reduced
from repro.models.model import Model
from repro.serve.admission import AdmissionConfig, InvalidRequest, \
    TERMINAL_STATES
from repro.serve.chaos import ChaosConfig, VirtualClock
from repro.serve.engine import Request, ServeEngine
from repro.serve.paging import PageLeak, PagePool
from repro.serve.reference import ReferenceEngine


@pytest.fixture(scope="module")
def parts():
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab, n,
                                                dtype=np.int32)


def _reference_outs(model, params, prompts, max_new, max_len=32,
                    eos_id=None):
    ref = ReferenceEngine(model, params, slots=2, max_len=max_len,
                         eos_id=eos_id)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        ref.submit(r)
    ref.run_to_completion(max_steps=2000)
    return {r.rid: list(r.out) for r in reqs}


# --------------------------------------------------------------------------
# allocator unit invariants
# --------------------------------------------------------------------------

def test_pool_reserve_map_release_roundtrip():
    pool = PagePool(n_pages=8, page_size=8, slots=2, max_len=32,
                    chunk_slack=4)
    # worst case: min(max_len, prompt+budget+slack) ceil-divided by pages
    assert pool.worst_pages(9, 7) == 3          # 9+7+4=20 -> 3 pages
    assert pool.worst_pages(30, 50) == 4        # clamped to max_len=32
    pool.reserve(0, 3)
    assert pool.map_to(0, 9) is True            # 2 pages mapped
    assert pool.pages_in_use == 2
    assert pool.map_to(0, 9) is False           # idempotent
    pool.map_to(0, 999)                         # clamps to the reservation
    assert len(pool.owned(0)) == 3
    pool.check()
    with pytest.raises(PageLeak):
        pool.reserve(0, 1)                      # double-reserve
    table = pool.table()
    assert table.shape == (2, 4)
    assert set(table[0, :3]) == set(pool.owned(0))
    assert (table[1] == pool.sentinel).all()
    pool.release(0)
    pool.assert_drained()


def test_pool_overflow_is_loud():
    pool = PagePool(n_pages=4, page_size=8, slots=4, max_len=32)
    pool.reserve(0, 3)
    assert not pool.can_reserve(2)
    with pytest.raises(PageLeak):
        pool.reserve(1, 2)


# --------------------------------------------------------------------------
# tentpole: token-exact paged serving, memory scaling, recycling
# --------------------------------------------------------------------------

def test_paged_token_exact_and_pool_drains(parts):
    cfg, model, params = parts
    prompts = [_prompt(cfg, n, n) for n in (4, 9, 6, 17, 12)]
    ref = _reference_outs(model, params, prompts, max_new=8)
    eng = ServeEngine(model, params, slots=2, max_len=32, decode_chunk=4,
                      paged=True, page_size=8)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=2000)
    assert {r.rid: list(r.out) for r in reqs} == ref
    eng._pool.assert_drained()
    # the two-slot engine served five requests: lanes were recycled at
    # chunk syncs rather than waiting for the next quantum's admit
    assert eng.recycled >= 1


def test_paged_kv_bytes_scale_with_live_context(parts):
    """Acceptance bound: mapped KV bytes <= 1.25x live tokens x per-token
    bytes at every post-admission quantum, and far under the dense
    slots x max_len reservation."""
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=4, max_len=128, decode_chunk=4,
                      paged=True, page_size=8)
    lens = (41, 44, 47, 43)
    reqs = [Request(rid=i, prompt=_prompt(cfg, n, i), max_new_tokens=16)
            for i, n in enumerate(lens)]
    for r in reqs:
        eng.submit(r)
    checked = 0
    for _ in range(200):
        if not eng.queue and not any(eng.active):
            break
        eng.step()
        s = eng.paged_kv_stats()
        if s["live_tokens"]:
            assert s["mapped_bytes"] <= \
                1.25 * s["live_tokens"] * s["kv_bytes_per_token"], s
            # and nowhere near the dense worst case for these contexts
            assert s["mapped_bytes"] < 0.6 * s["dense_bytes"], s
            checked += 1
    assert checked >= 3, "never observed a live steady state"
    assert all(r.state == "done" for r in reqs)
    eng._pool.assert_drained()


def test_midchunk_eos_hands_slot_over_without_idle_chunk(parts):
    """A lane that hits EOS inside a chunk is re-armed from the queue at
    that same chunk sync: after the step() call in which r1 died, r2 is
    already running with its prefill token — no intervening quantum, no
    idle chunk."""
    cfg, model, params = parts
    p1 = _prompt(cfg, 6, 3)
    p2 = _prompt(cfg, 5, 4)
    # discover a token r1 actually emits mid-chunk, then replay with it
    # as the EOS id (budget 12 -> chunks of 8: out[3] dies at scan step 3)
    probe = _reference_outs(model, params, [p1], max_new=12)[0]
    eos = probe[3]
    if eos in probe[:3] or eos == probe[0]:
        eos = probe[4]                      # avoid an earlier accidental hit
    eng = ServeEngine(model, params, slots=1, max_len=64, decode_chunk=8,
                      eos_id=eos, paged=True, page_size=8)
    r1 = Request(rid=1, prompt=p1, max_new_tokens=12)
    r2 = Request(rid=2, prompt=p2, max_new_tokens=4)
    eng.submit(r1)
    eng.submit(r2)
    handoff_seen = False
    for _ in range(50):
        if not eng.queue and not any(eng.active):
            break
        live = eng.step()
        assert live > 0, "idle chunk: work pending but no lanes live"
        if r1.finished and not handoff_seen:
            handoff_seen = True
            # the SAME step that retired r1 must have re-armed r2
            assert r2.state == "running" and len(r2.out) >= 1, \
                (r2.state, r2.out)
    assert r1.state == "done" and r1.out[-1] == eos
    assert r2.state == "done"
    assert handoff_seen
    assert eng.recycled >= 1
    eng._pool.assert_drained()


def test_paged_admission_queues_on_pages_not_slots(parts):
    """Oversubscribed pool (kv_pages << slots x max_len / page_size): the
    page reservation, not slot count, caps concurrency; blocked requests
    wait queued and everything still completes."""
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=6, max_len=64, decode_chunk=4,
                      paged=True, page_size=8, kv_pages=16)
    # worst case per request: ceil((20 + 3 + 4)/8) = 4 pages -> only 4 of
    # the 6 lanes can hold a reservation at once
    reqs = [Request(rid=i, prompt=_prompt(cfg, 20, i), max_new_tokens=4)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    max_live = 0
    for _ in range(200):
        if not eng.queue and not any(eng.active):
            break
        eng.step()
        eng._pool.check()
        assert eng._pool.reserved_pages <= eng._pool.n_pages
        max_live = max(max_live, sum(r is not None for r in eng.active))
    assert all(r.state == "done" for r in reqs)
    assert max_live <= 4, "pages should cap concurrency below slot count"
    eng._pool.assert_drained()


def test_request_larger_than_pool_rejected_at_submit(parts):
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=2, max_len=64, decode_chunk=4,
                      paged=True, page_size=8, kv_pages=4)
    big = Request(rid=1, prompt=_prompt(cfg, 40), max_new_tokens=4)
    eng.submit(big)
    assert big.state == "rejected" and big.reason == "pages-exhausted"
    hungry = Request(rid=2, prompt=_prompt(cfg, 8), max_new_tokens=40)
    eng.submit(hungry)
    assert hungry.state == "rejected" and hungry.reason == "pages-exhausted"
    ok = Request(rid=3, prompt=_prompt(cfg, 8), max_new_tokens=4)
    eng.submit(ok)
    eng.run_to_completion(max_steps=200)
    assert ok.state == "done"
    eng._pool.assert_drained()


def test_paged_validation(parts):
    cfg, model, params = parts
    with pytest.raises(ValueError, match="bucketed"):
        ServeEngine(model, params, slots=2, max_len=32, paged=True,
                    prefill_buckets=False)
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(model, params, slots=2, max_len=32, paged=True,
                    page_size=7)
    eng = ServeEngine(model, params, slots=2, max_len=32, paged=True,
                      page_size=8)
    with pytest.raises(InvalidRequest, match="extras"):
        eng.submit(Request(rid=1, prompt=_prompt(cfg, 4),
                           extras={"frames": np.zeros((1, 2, 4))}))
    # MLA families refuse a paged cache outright
    mla_cfg = reduced(get_arch("deepseek-v2-236b"))
    with pytest.raises(ValueError):
        Model(mla_cfg).init_cache(2, 32, page_size=8, kv_pages=8)


def test_paged_off_has_no_pool_and_no_recycle(parts):
    """paged=False must build the identical engine the bit-identity gates
    in tests/test_serving.py / test_admission.py compare against the
    seed: no pool, no recycling admit pass."""
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=2, max_len=32)
    assert eng._pool is None and eng.recycle is False
    with pytest.raises(ValueError):
        eng.paged_kv_stats()


def test_recycle_handoff_replays_step_locked_in_tracer(parts):
    """tenancy/trace.py lowering stays exact under recycling: a recycled
    lane's prefill is recorded at the chunk sync it happened in (stamped
    at/after every event of the chunk that freed the lane), the event
    stream accounts for every served token, and the time-ordered stream
    lowers to a GemmSpec chain without error."""
    from repro.tenancy.trace import ServeTraceRecorder, trace_to_gemms
    cfg, model, params = parts
    rec = ServeTraceRecorder()
    eng = ServeEngine(model, params, slots=2, max_len=32, decode_chunk=4,
                      tracer=rec, paged=True, page_size=8)
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4 + i, i), max_new_tokens=5)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=200)
    assert all(r.state == "done" for r in reqs)
    assert eng.recycled >= 1
    assert rec.num_prefills == len(reqs)
    # every decode-emitted token shows up in the event stream (prefill
    # produces each request's first token; decode events carry the rest)
    assert rec.phase_tokens("decode") == \
        sum(len(r.out) for r in reqs) - len(reqs)
    # stamps are non-decreasing once sorted the way the lowering sorts —
    # and a recycled prefill never lands BEFORE the chunk that freed it
    stamps = [e[-1] for e in rec.events]
    order = sorted(range(len(stamps)), key=lambda i: stamps[i])
    prefills_seen = 0
    for i in order:
        if rec.events[i][0] == "prefill":
            prefills_seen += 1
    assert prefills_seen == len(reqs)
    gemms = trace_to_gemms(rec, cfg)
    assert gemms and all(g.d1 >= 1 for g in gemms)
    eng._pool.assert_drained()


# --------------------------------------------------------------------------
# chaos: fault paths must not leak pages
# --------------------------------------------------------------------------

def test_paged_faults_do_not_leak_pages(parts):
    """transient_tries > max_retries: calls escalate to PermanentFault and
    requests shed — every affected lane's pages must return to the pool
    (the _release_slot discipline on every death path)."""
    cfg, model, params = parts
    eng = ServeEngine(model, params, slots=2, max_len=32, decode_chunk=4,
                      max_retries=1, paged=True, page_size=8,
                      chaos=ChaosConfig(seed=1, p_fault=0.4,
                                        transient_tries=5))
    reqs = [Request(rid=i, prompt=_prompt(cfg, 4 + i, i), max_new_tokens=3)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=2000)
    assert any(r.state == "rejected" for r in reqs), \
        "seed 1 must trip at least one permanent fault"
    assert all(r.state in TERMINAL_STATES for r in reqs)
    eng._pool.assert_drained()


# --------------------------------------------------------------------------
# property test: randomized paged traffic, chaos armed
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), with_chaos=st.booleans(),
       policy=st.sampled_from(["fifo", "edf", "slo-aware"]))
def test_paged_random_traffic_page_invariants(parts, seed, with_chaos,
                                              policy):
    """Page-pool invariants under randomized traffic: at every quantum
    {free} + {owned} exactly partition the pool (no lane can even address
    a page it doesn't own — the table only carries owned ids), at drain
    pages allocated == pages freed, and every request the engine finished
    is token-exact (prefix under budget degradation) vs the bare
    ReferenceEngine oracle — recycled lanes included."""
    cfg, model, params = parts
    rng = np.random.default_rng(seed)
    slots = int(rng.integers(1, 4))
    chaos = ChaosConfig(seed=seed, p_fault=0.2, p_slow=0.2,
                        service_seconds=0.02, transient_tries=1) \
        if with_chaos else None
    eng = ServeEngine(model, params, slots=slots, max_len=32,
                      decode_chunk=4, clock=VirtualClock(),
                      paged=True, page_size=8,
                      kv_pages=int(rng.integers(2, 5)) * slots,
                      admission=AdmissionConfig(
                          policy=policy,
                          max_queue=int(rng.integers(2, 8))),
                      chaos=chaos)
    reqs = []
    for i in range(int(rng.integers(1, 9))):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(1, 33)),
                                dtype=np.int32),
            max_new_tokens=int(rng.integers(2, 7)),
            deadline_s=float(rng.uniform(0.05, 2.0))
            if rng.random() < 0.5 else None,
            priority=int(rng.integers(0, 3))))
    for r in reqs:
        eng.submit(r)
        eng.step()
        eng._pool.check()
    for _ in range(2000):
        if not eng.queue and not any(eng.active):
            break
        eng.step()
        eng._pool.check()
        assert eng._pool.reserved_pages <= eng._pool.n_pages
    assert not any(eng.active) and not eng.queue
    assert all(r.state in TERMINAL_STATES for r in reqs)
    eng._pool.assert_drained()
    done = [r for r in reqs if r.state == "done"]
    if done:
        oracle = ReferenceEngine(model, params, slots=2, max_len=32)
        oreqs = [Request(rid=r.rid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in done]
        for r in oreqs:
            oracle.submit(r)
        oracle.run_to_completion(max_steps=2000)
        want = {r.rid: list(r.out) for r in oreqs}
        for r in done:
            assert list(r.out) == want[r.rid][:len(r.out)], r.rid
            assert len(r.out) >= 1
