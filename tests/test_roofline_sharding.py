"""Roofline HLO parsing, sharding rules, autoshard decisions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, get_arch
from repro.models.layers import ParamSpec
from repro.parallel.autoshard import (choose_blocks, choose_plan,
                                      device_gemms, tiles_exposed)
from repro.parallel.sharding import (pspec_for_axes, zero1_pspec)
from repro.roofline.analysis import (Roofline, collective_bytes_from_hlo,
                                     _shape_bytes)


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[4,4]") == 64
    assert _shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert _shape_bytes("token[]") == 0


def test_collective_bytes_from_real_compile():
    """Compile a psum under 8 fake devices in a subprocess-free way: use
    a synthetic HLO snippet shaped like XLA output."""
    hlo = """
HloModule m
ENTRY e {
  %p0 = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[64,64]{1,0} all-gather(%p0), dimensions={0}
  %cp = f32[16,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  ROOT %t = (f32[16,64]{1,0}) tuple(%cp)
}
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 16 * 64 * 4
    assert out["all-gather"] == 16 * 64 * 4      # operand, not result
    assert out["collective-permute"] == 16 * 64 * 4
    assert out["total"] == 3 * 16 * 64 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(name="x", chips=256, flops_per_device=197e12,
                 bytes_per_device=819e9 * 2,
                 collective_bytes_per_device=50e9 * 0.5)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert abs(r.collective_s - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    mf = 197e12 * 256  # exactly 1s of useful work at peak
    assert abs(r.roofline_fraction(mf) - 0.5) < 1e-9


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

def _mesh():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def test_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # model axis size 1: everything "divides" -> sharded on size-1 axis ok
    s = pspec_for_axes(("embed", "heads", None), (64, 12, 16), mesh)
    assert s == P(None, "model", None)


def test_zero1_idempotent_and_guarded():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    base = P(None, "model")
    z = zero1_pspec(base, (64, 128), mesh)
    assert z[0] == "data"
    assert zero1_pspec(z, (64, 128), mesh) == z  # idempotent


# --------------------------------------------------------------------------
# autoshard (the paper's tiling criterion at mesh scale)
# --------------------------------------------------------------------------

def test_choose_blocks_mxu_aligned():
    bm, bn, bk = choose_blocks(4096, 4096, 11008)
    assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
    # VMEM budget respected
    assert 2 * 3 * (bm * bk + bk * bn + bm * bn) <= 12 * 2 ** 20


def test_small_gemm_gets_small_blocks():
    big = choose_blocks(8192, 8192, 8192)
    small = choose_blocks(256, 256, 256)
    assert small[0] <= big[0] and small[2] <= big[2]


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v2-236b"])
def test_plan_exposes_enough_tiles(arch):
    cfg = get_arch(arch)
    mesh_shape = {"data": 16, "model": 16}
    plan, table = choose_plan(cfg, SHAPES["train_4k"], mesh_shape)
    gemms = device_gemms(cfg, SHAPES["train_4k"], plan)
    assert tiles_exposed(gemms) >= 1
    assert len(table) >= 2
    # train plans consider sequence parallel + microbatching
    assert any("sp=True" in desc for desc, _ in table)
