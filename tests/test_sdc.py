"""SDC-safe pod GEMMs and degraded-pod operation.

Covers the robustness envelope end to end:

  * ABFT checksum math: single-element corruptions detected, located to
    the exact element (and tile), and repaired bit-tight; multi-element
    hits stay uncorrectable; checksum-row hits leave the data untouched.
  * Freivalds probe: a lone corruption is always caught; the adversarial
    miss rate obeys the documented <= 2**-probes bound.
  * PodGuard=off is bit-identical to the seed engine — tokens, jit cache
    sizes, and host sync counts (the PR-7 zero-overhead discipline).
  * Chaos SDC plans: deterministic, replayed across retries, then healed.
  * Engine integration: injected SDC under abft is corrected and the
    stream stays token-exact vs the oracle; exhausted retries terminate
    as ``sdc-uncorrectable`` with zero slot leaks; NaN/Inf logits shed
    exactly the poisoned lanes in BOTH engines.
  * Degraded pods: retiling avoids dead banks/pods, the analytical
    predictions are monotone in masked pods and track the slice
    scheduler, and the admission predictor prices the degraded array.
  * Checkpoint integrity: sha256-validated restore rejects torn shards
    with a typed error naming the file.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch, reduced
from repro.core.dse import build_accel
from repro.core.scheduler import SliceScheduler
from repro.core.simulator import (DesignVector, analyze, analyze_batch,
                                  analyze_scalar, pack_workloads, simulate)
from repro.core.tiling import GemmSpec, tile_workload
from repro.kernels.systolic_gemm.guard import (GuardTape, PodGuard, abft_verify,
                                               as_guard, augment_w, augment_x,
                                               freivalds_detect, guarded_gemm,
                                               inject_sdc, tile_of)
from repro.models.model import Model
from repro.serve.admission import AdmissionConfig, WaveLatencyPredictor
from repro.serve.chaos import (ChaosConfig, FaultInjector, NumericalFault,
                               VirtualClock, check_lanes_finite)
from repro.serve.engine import Request, ServeEngine
from repro.serve.reference import ReferenceEngine
from repro.train.checkpoint import (CheckpointCorrupt, restore_checkpoint,
                                    save_checkpoint)


def _setup(seed=0, **model_kw):
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg, **model_kw)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _reqs(n=4, max_new=6):
    return [Request(rid=i, prompt=[1 + i, 2, 3 + i], max_new_tokens=max_new)
            for i in range(n)]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=500)
    assert all(s is None for s in eng.active), "slot leak"
    return {r.rid: (r.state, r.reason, list(r.out)) for r in reqs}


# --------------------------------------------------------------------------
# ABFT math (property-based over shapes/dtypes/corruption sites)
# --------------------------------------------------------------------------

def _abft_case(m, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    c_aug = jnp.dot(augment_x(x).astype(jnp.float32),
                    augment_w(w).astype(jnp.float32))
    return x, w, c_aug


@pytest.mark.tier1
@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 24), k=st.integers(2, 32), n=st.integers(2, 24),
       dt=st.sampled_from(["float32", "bfloat16"]),
       seed=st.integers(0, 10_000))
def test_abft_single_corruption_detected_located_corrected(m, k, n, dt,
                                                           seed):
    """100% detection of single-element corruptions, located to the right
    element (hence the right tile), and repaired to the clean value."""
    x, w, c_aug = _abft_case(m, k, n, jnp.dtype(dt), seed)
    clean = np.asarray(c_aug)[:m, :n]
    rng = np.random.default_rng(seed + 1)
    r, cc = int(rng.integers(m)), int(rng.integers(n))
    bad = c_aug.at[r, cc].add(1e4)
    out, rep = abft_verify(bad, x, w, rtol=1.0 / 64)
    assert int(rep["detected"]) == 1
    assert int(rep["corrected"]) == 1 and int(rep["uncorrected"]) == 0
    assert (int(rep["row"]), int(rep["col"])) == (r, cc)
    assert tile_of(int(rep["row"]), int(rep["col"]), 8, 8) == (r // 8, cc // 8)
    np.testing.assert_allclose(np.asarray(out), clean, rtol=1e-5, atol=1e-5)


@pytest.mark.tier1
@settings(max_examples=10, deadline=None)
@given(m=st.integers(3, 24), n=st.integers(3, 24), seed=st.integers(0, 9999))
def test_abft_multi_corruption_stays_uncorrectable(m, n, seed):
    """Two corruptions on distinct rows AND columns cannot be located as
    one — detection holds, correction must refuse (engine recomputes)."""
    x, w, c_aug = _abft_case(m, 16, n, jnp.float32, seed)
    rng = np.random.default_rng(seed)
    r0, c0 = int(rng.integers(m - 1)), int(rng.integers(n - 1))
    bad = c_aug.at[r0, c0].add(1e4).at[r0 + 1, c0 + 1].add(-3e3)
    _, rep = abft_verify(bad, x, w, rtol=1.0 / 64)
    assert int(rep["detected"]) == 1
    assert int(rep["corrected"]) == 0 and int(rep["uncorrected"]) == 1


@pytest.mark.tier1
def test_abft_clean_and_checksum_only_cases():
    """No false positives on a clean product; a hit confined to the
    checksum row leaves the (clean) data block untouched and corrected."""
    x, w, c_aug = _abft_case(12, 16, 10, jnp.bfloat16, 3)
    out, rep = abft_verify(c_aug, x, w, rtol=1.0 / 64)
    assert int(rep["detected"]) == 0
    np.testing.assert_array_equal(np.asarray(out), np.asarray(c_aug)[:12, :10])
    bad = c_aug.at[12, 4].add(1e4)        # checksum row only
    out2, rep2 = abft_verify(bad, x, w, rtol=1.0 / 64)
    assert int(rep2["detected"]) == 1 and int(rep2["corrected"]) == 1
    np.testing.assert_array_equal(np.asarray(out2),
                                  np.asarray(c_aug)[:12, :10])


@pytest.mark.tier1
@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 20), k=st.integers(2, 24), n=st.integers(2, 20),
       seed=st.integers(0, 9999))
def test_probe_single_corruption_always_detected(m, k, n, seed):
    """A lone corrupted element shifts its row residual by exactly
    +-delta — one Freivalds probe cannot miss it."""
    x, w, c_aug = _abft_case(m, k, n, jnp.float32, seed)
    c = c_aug[:m, :n]
    assert int(freivalds_detect(c, x, w, probes=1, seed=seed,
                                rtol=1.0 / 64)) == 0
    rng = np.random.default_rng(seed)
    bad = c.at[int(rng.integers(m)), int(rng.integers(n))].add(1e4)
    assert int(freivalds_detect(bad, x, w, probes=1, seed=seed,
                                rtol=1.0 / 64)) == 1


@pytest.mark.tier1
def test_probe_adversarial_miss_rate_obeys_documented_bound():
    """The +delta/-delta same-row pattern escapes one probe iff the
    Rademacher vector agrees at both columns (p = 1/2 per probe); the
    measured miss rate must respect <= 2**-probes (with sampling slack),
    and extra probes must shrink it."""
    x, w, c_aug = _abft_case(8, 16, 12, jnp.float32, 0)
    c = c_aug[:8, :12]
    bad = c.at[3, 2].add(1e4).at[3, 9].add(-1e4)
    trials = 200
    misses = {p: sum(
        int(freivalds_detect(bad, x, w, probes=p, seed=s,
                             rtol=1.0 / 64)) == 0
        for s in range(trials)) / trials for p in (1, 3)}
    assert misses[1] <= 0.5 + 0.12          # bound 2**-1 plus sampling slack
    assert misses[3] <= 0.125 + 0.08        # bound 2**-3 plus sampling slack
    assert misses[3] < misses[1]


@pytest.mark.tier1
def test_guarded_gemm_matches_fused_epilogue_and_rejects_int8():
    """Standalone guarded GEMM (abft + probe) reproduces the fused-kernel
    epilogue output exactly on clean inputs; int8 + abft is refused."""
    from repro.kernels.systolic_gemm.ops import fused_lane_gemm
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
    scale = jnp.asarray(rng.standard_normal(12), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(12), jnp.float32)
    raw = fused_lane_gemm(x, w, interpret=True)
    fused = fused_lane_gemm(x, w, scale, bias, activation="gelu",
                            interpret=True)
    for mode in ("abft", "probe"):
        # identity epilogue: the raw accumulator must survive the guard
        # exactly (checksums never perturb the data block)
        np.testing.assert_array_equal(
            np.asarray(guarded_gemm(x, w, guard=PodGuard(mode=mode),
                                    interpret=True)),
            np.asarray(raw))
        # full epilogue: same math, but the fused kernel applies it under
        # jit while the guard applies it eagerly -> ulp-level differences
        out = guarded_gemm(x, w, scale, bias, guard=PodGuard(mode=mode),
                           activation="gelu", interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(fused),
                                   rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="int8"):
        guarded_gemm(x.astype(jnp.int8), w.astype(jnp.int8),
                     guard=PodGuard(mode="abft"), interpret=True)


@pytest.mark.tier1
def test_guard_config_validation():
    assert as_guard(None).mode == "off"
    assert as_guard("abft").mode == "abft"
    assert as_guard(PodGuard(mode="probe")).mode == "probe"
    with pytest.raises(ValueError):
        PodGuard(mode="bogus")
    with pytest.raises(ValueError):
        PodGuard(rtol=2.0)
    with pytest.raises(TypeError):
        as_guard(42)


@pytest.mark.tier1
def test_inject_sdc_hits_distinct_rows_and_cols():
    """n_elems=2 lands on distinct rows AND columns — the pattern that
    provably defeats single-corruption ABFT location."""
    c = jnp.zeros((6, 5), jnp.float32)
    out = np.asarray(inject_sdc(c, 0, (0, 123, 2), 1e4, 6, 5))
    rows, cols = np.nonzero(out)
    assert len(rows) == 2
    assert rows[0] != rows[1] and cols[0] != cols[1]
    # disarmed plans and index misses are exact no-ops
    assert not np.asarray(inject_sdc(c, 0, (-1, 123, 2), 1e4, 6, 5)).any()
    assert not np.asarray(inject_sdc(c, 1, (0, 123, 2), 1e4, 6, 5)).any()


# --------------------------------------------------------------------------
# chaos SDC plans
# --------------------------------------------------------------------------

@pytest.mark.tier1
def test_sdc_plan_deterministic_replay_then_heal():
    """A corrupt site replays the SAME plan for transient_tries attempts,
    then heals; the schedule is a pure function of the seed."""
    cfg = ChaosConfig(seed=5, p_sdc=1.0, sdc_elems=2, sdc_target=1,
                      transient_tries=2)
    a, b = FaultInjector(cfg), FaultInjector(cfg)
    p1, p2, p3 = (a.sdc_plan("decode") for _ in range(3))
    assert p1 == p2 and p1 is not None          # replayed verbatim
    assert p1[0] == 1 and p1[2] == 2
    assert p3 is None                           # healed, site consumed
    assert a.injected["sdc"] == 2
    assert [b.sdc_plan("decode") for _ in range(3)] == [p1, p2, p3]
    # p_sdc=0 short-circuits
    off = FaultInjector(ChaosConfig(seed=5))
    assert off.sdc_plan("decode") is None and off.injected["sdc"] == 0


# --------------------------------------------------------------------------
# PodGuard=off bit-identity (the PR-7 zero-overhead discipline)
# --------------------------------------------------------------------------

class _SyncCountingNumpy:
    """numpy proxy counting device->host materializations (np.asarray on a
    jax.Array) — the engine's host-sync accounting unit."""

    def __init__(self, real):
        self._real = real
        self.syncs = 0

    def asarray(self, x, *a, **k):
        if isinstance(x, jax.Array):
            self.syncs += 1
        return self._real.asarray(x, *a, **k)

    def __getattr__(self, name):
        return getattr(self._real, name)


@pytest.mark.tier1
def test_guard_off_bit_identical_to_seed_engine(monkeypatch):
    """guard='off' must change NOTHING: same tokens, same jit cache
    sizes, same host sync count as an engine that never heard of guards."""
    import repro.serve.engine as engine_mod
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9, 17, 12)]

    runs = {}
    for name, kw in (("bare", {}), ("off", {"guard": "off"})):
        proxy = _SyncCountingNumpy(np)
        monkeypatch.setattr(engine_mod, "np", proxy)
        eng = ServeEngine(model, params, slots=2, max_len=64, **kw)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion(max_steps=500)
        runs[name] = ({r.rid: r.out for r in reqs},
                      eng._prefill_fn._cache_size(),
                      eng._decode_fn._cache_size(),
                      proxy.syncs)
        monkeypatch.setattr(engine_mod, "np", np)
    assert runs["off"] == runs["bare"]


# --------------------------------------------------------------------------
# non-finite logits: typed fault, exact lanes shed, both engines
# --------------------------------------------------------------------------

class _PoisonModel:
    """Delegates to the real model; turns logits to NaN for every lane
    whose trigger token shows up (first prompt token in prefill, current
    token in decode) — a deterministic stand-in for numerical blowup."""

    def __init__(self, inner, bad_tok, where):
        self._inner, self._bad, self._where = inner, int(bad_tok), where

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def forward(self, params, batch, cache=None, positions=None,
                true_lens=None):
        logits, c = self._inner.forward(params, batch, cache, positions,
                                        true_lens)
        if self._where == "prefill":
            hit = batch["tokens"][:, 0] == self._bad
            logits = jnp.where(hit[:, None, None], jnp.nan, logits)
        return logits, c

    def prefill(self, params, batch, cache):
        logits, c = self._inner.prefill(params, batch, cache)
        if self._where == "prefill":
            hit = batch["tokens"][:, 0] == self._bad
            logits = jnp.where(hit[:, None], jnp.nan, logits)
        return logits, c

    def decode_step(self, params, toks, cache, positions):
        logits, c = self._inner.decode_step(params, toks, cache, positions)
        if self._where == "decode":
            logits = jnp.where((toks == self._bad)[:, None], jnp.nan,
                               logits)
        return logits, c


@pytest.mark.tier1
def test_check_lanes_finite_raises_typed_fault():
    check_lanes_finite([(0, False), (1, False)])          # no-op
    with pytest.raises(NumericalFault) as exc:
        check_lanes_finite({0: False, 2: True, 3: True}, where="prefill")
    assert exc.value.lanes == [2, 3] and exc.value.where == "prefill"


@pytest.mark.tier1
@pytest.mark.parametrize("engine_cls", [ServeEngine, ReferenceEngine])
def test_non_finite_prefill_sheds_only_poisoned_lane(engine_cls):
    """A NaN prefill rejects that request (non-finite-logits) and leaves
    every other lane serving normally — in both engines."""
    cfg, model, params = _setup()
    poisoned = _PoisonModel(model, bad_tok=2, where="prefill")  # rid 1
    states = _drain(engine_cls(poisoned, params, slots=4, max_len=64),
                    _reqs())
    assert states[1][:2] == ("rejected", "non-finite-logits")
    assert all(st == "done" for rid, (st, _, _) in states.items()
               if rid != 1)


@pytest.mark.tier1
@pytest.mark.parametrize("engine_cls", [ServeEngine, ReferenceEngine])
def test_non_finite_decode_sheds_only_poisoned_lane(engine_cls):
    """Mid-decode NaN sheds exactly the poisoned lane; its emitted tokens
    stop at the poison point and no slot leaks."""
    cfg, model, params = _setup()
    ref = ReferenceEngine(model, params, slots=4, max_len=64)
    clean = _drain(ref, _reqs())
    bad_tok = clean[2][2][1]          # rid 2's 2nd token triggers mid-decode
    poisoned = _PoisonModel(model, bad_tok=bad_tok, where="decode")
    eng = engine_cls(poisoned, params, slots=4, max_len=64)
    states = _drain(eng, _reqs())
    shed = [rid for rid, (s, why, _) in states.items()
            if (s, why) == ("rejected", "non-finite-logits")]
    assert shed, states
    assert eng.guard_events["non_finite"] == len(shed)
    assert any(st == "done" for st, _, _ in states.values())


# --------------------------------------------------------------------------
# engine e2e: SDC under guard (pallas path; slow)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pallas_parts():
    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg, use_pallas=True)
    params = model.init(jax.random.PRNGKey(0))
    ref = ReferenceEngine(Model(cfg), params, slots=4, max_len=64)
    oracle = _drain(ref, _reqs())
    return cfg, model, params, oracle


@pytest.mark.slow
def test_abft_corrects_injected_sdc_token_exact(pallas_parts):
    """Single-element SDC under abft: detected, corrected in-graph, and
    the stream stays token-exact against the clean oracle."""
    cfg, model, params, oracle = pallas_parts
    chaos = ChaosConfig(seed=7, p_sdc=0.6, sdc_elems=1, transient_tries=1)
    eng = ServeEngine(model, params, slots=4, max_len=64, guard="abft",
                      chaos=chaos, clock=VirtualClock(), max_retries=3)
    states = _drain(eng, _reqs())
    assert eng._chaos.injected["sdc"] > 0, "chaos never armed a plan"
    assert eng.guard_events["corrected"] > 0
    assert eng.guard_events["uncorrectable"] == 0
    for rid, (state, _, out) in states.items():
        assert state == "done" and out == oracle[rid][2]


@pytest.mark.slow
def test_multi_element_sdc_exhausts_retries_no_slot_leak(pallas_parts):
    """2-element corruption defeats ABFT location on every retry: the
    affected requests end ``sdc-uncorrectable`` and no slot leaks."""
    cfg, model, params, _ = pallas_parts
    chaos = ChaosConfig(seed=7, p_sdc=0.9, sdc_elems=2, transient_tries=10)
    eng = ServeEngine(model, params, slots=4, max_len=64, guard="abft",
                      chaos=chaos, clock=VirtualClock(), max_retries=1)
    states = _drain(eng, _reqs())
    rejected = [rid for rid, (s, why, _) in states.items()
                if (s, why) == ("rejected", "sdc-uncorrectable")]
    assert rejected, states
    assert eng.guard_events["uncorrectable"] > 0


@pytest.mark.slow
def test_probe_detects_then_retry_heals_token_exact(pallas_parts):
    """Detect-only probe mode: corruption triggers recompute-and-retry;
    the site heals within the retry budget and tokens stay exact."""
    cfg, model, params, oracle = pallas_parts
    chaos = ChaosConfig(seed=7, p_sdc=0.6, sdc_elems=1, transient_tries=1)
    eng = ServeEngine(model, params, slots=4, max_len=64, guard="probe",
                      chaos=chaos, clock=VirtualClock(), max_retries=3)
    states = _drain(eng, _reqs())
    assert eng._chaos.injected["sdc"] > 0
    assert eng.guard_events["uncorrectable"] == 0
    for rid, (state, _, out) in states.items():
        assert state == "done" and out == oracle[rid][2]


# --------------------------------------------------------------------------
# degraded pods: retiling, scheduling, predictions, admission pricing
# --------------------------------------------------------------------------

_GEMMS = [GemmSpec(128, 256, 512, gemm_id=0),
          GemmSpec(128, 512, 256, gemm_id=1, depends_on=(0,))]


@pytest.mark.tier1
def test_tiling_masks_faulty_banks_and_empty_mask_is_seed():
    accel = build_accel(32, 32, "butterfly-2", 400.0, 16)
    seed = tile_workload(_GEMMS, accel.array, num_banks=16)
    same = tile_workload(_GEMMS, accel.array, num_banks=16, faulty_banks=())
    assert seed.ops == same.ops
    masked = tile_workload(_GEMMS, accel.array, num_banks=16,
                           faulty_banks=(0, 3))
    used = {b for op in masked.ops
            for b in (op.x_bank, op.w_bank, op.p_bank)}
    assert not used & {0, 3}
    assert len(masked.ops) == len(seed.ops)     # same tile count, remapped
    with pytest.raises(ValueError):
        tile_workload(_GEMMS, accel.array, num_banks=4,
                      faulty_banks=(0, 1, 2, 3))


@pytest.mark.tier1
def test_scheduler_places_only_on_healthy_pods():
    accel = build_accel(32, 32, "butterfly-2", 400.0, 16)
    graph = tile_workload(_GEMMS, accel.array, num_banks=16,
                          faulty_banks=(1, 2))
    sched = SliceScheduler(16, 32, accel.array.pipeline_latency,
                           faulty_pods=(1, 2)).schedule(graph)
    assert len(sched.assignments) == len(graph.ops)
    assert not {p for _, p in sched.assignments.values()} & {1, 2}
    with pytest.raises(ValueError):
        SliceScheduler(4, 32, 4, faulty_pods=(0, 1, 2, 3))
    with pytest.raises(ValueError):
        SliceScheduler(4, 32, 4, faulty_pods=(7,))


@pytest.mark.tier1
def test_degraded_predictions_monotone_and_match_scheduler():
    """analyze/analyze_batch latency rises monotonically as pods die, the
    batched and scalar paths agree, and the analytical prediction stays
    within the calibrated band of the real slice scheduler."""
    accel = build_accel(32, 32, "butterfly-2", 400.0, 16)
    cycles = [analyze(_GEMMS, accel, faulty_pods=f).total_cycles
              for f in range(0, 15)]
    assert all(b >= a for a, b in zip(cycles, cycles[1:]))
    assert cycles[-1] > cycles[0]

    packed = pack_workloads({"wl": _GEMMS})
    design = DesignVector.from_accel(accel).repeat(4)
    batch = analyze_batch(packed, design,
                          faulty_pods=np.array([0, 2, 6, 12]))
    col = batch.total_cycles[:, 0]
    assert all(b >= a for a, b in zip(col, col[1:]))
    for p, f in enumerate((0, 2, 6, 12)):
        sc = analyze_scalar(_GEMMS, accel, faulty_pods=f)
        assert abs(sc.total_cycles - int(col[p])) <= 1

    for f in (0, 4, 8):
        pred = analyze(_GEMMS, accel, faulty_pods=f).total_cycles
        real = simulate(_GEMMS, accel, faulty_pods=f).total_cycles
        assert 0.5 <= pred / real <= 2.0, (f, pred, real)

    with pytest.raises(ValueError):
        analyze_batch(packed, DesignVector.from_accel(accel),
                      faulty_pods=16)


@pytest.mark.tier1
def test_admission_predictor_prices_degraded_array():
    """The slo-aware predictor sees longer service on a degraded design,
    so admission sheds load proportionally to lost capacity."""
    cfg = reduced(get_arch("granite-8b"))
    design = (32, 32, "butterfly-2", 16)
    healthy = WaveLatencyPredictor(cfg, design, faulty_pods=0)
    degraded = WaveLatencyPredictor(cfg, design, faulty_pods=12)
    t0 = healthy.model_seconds(64, 32)
    t1 = degraded.model_seconds(64, 32)
    assert t1 > t0
    with pytest.raises(ValueError):
        AdmissionConfig(design=design, faulty_pods=16)
    AdmissionConfig(design=design, faulty_pods=3)       # in range: fine


# --------------------------------------------------------------------------
# checkpoint integrity
# --------------------------------------------------------------------------

@pytest.mark.tier1
def test_checkpoint_checksum_detects_truncated_write(tmp_path):
    """Atomic save records a sha256 per shard; restore re-hashes before
    np.load and raises the typed error naming the torn file."""
    import json
    tree = {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "b": jnp.ones(4, jnp.float32)}
    d = str(tmp_path)
    path = save_checkpoint(d, 3, tree)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert "shard_0.npz" in meta["checksums"]

    out, step = restore_checkpoint(d, tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["b"]), np.ones(4))

    shard = os.path.join(path, "shard_0.npz")
    with open(shard, "rb") as f:
        raw = f.read()
    with open(shard, "wb") as f:                 # simulate a torn write
        f.write(raw[: len(raw) // 2])
    with pytest.raises(CheckpointCorrupt) as exc:
        restore_checkpoint(d, tree)
    assert "shard_0.npz" in exc.value.path
    assert "sha256" in exc.value.detail

    # pre-checksum checkpoints (no "checksums" key) still restore
    with open(shard, "wb") as f:
        f.write(raw)
    meta.pop("checksums")
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    out2, _ = restore_checkpoint(d, tree)
    np.testing.assert_array_equal(
        np.asarray(out2["w"], np.float32),
        np.asarray(tree["w"], np.float32))
