"""Cross-family serving parity matrix.

Every model family in configs/all_archs.py x use_pallas {off, on} must
produce token-exact output from the optimized ServeEngine (bucketed
prefill + fused decode + pod-GEMM execution backend) vs the seed
per-token serve.ReferenceEngine oracle. This is the end-to-end gate for
the decode-gap closure: MoE grouped dispatch, the transposed-weight
LM-head, and the stateful (SSM/ring) bucketed prefill all sit under it.

The full matrix is `slow`; a one-arch-per-new-bucketed-family subset
runs in the fast (`-m "not slow"`) tier-1 gate.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.all_archs import ALL_ARCHS
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine
from repro.serve.reference import ReferenceEngine

# One representative arch per family, plus both MoE archs (deepseek-v2
# exercises MLA + shared experts + first-dense-layer segmentation, dbrx
# plain GQA MoE).
MATRIX_ARCHS = [
    "granite-8b",            # dense
    "deepseek-v2-236b",      # moe (MLA, shared experts)
    "dbrx-132b",             # moe (GQA)
    "whisper-small",         # audio (encoder-decoder)
    "llama-3.2-vision-90b",  # vlm (cross-attention image layers)
    "mamba2-370m",           # ssm (tied embeddings -> transposed LM head)
    "hymba-1.5b",            # hybrid (SWA ring caches + SSM)
]

SRC_LEN = 8


def test_matrix_covers_every_family():
    """The parity matrix must not silently lose a family when
    configs/all_archs.py grows."""
    covered = {get_arch(a).family for a in MATRIX_ARCHS}
    assert covered == {get_arch(a).family for a in ALL_ARCHS}


def _extras(cfg, rng):
    if cfg.encoder_decoder:
        return {"frames": rng.standard_normal(
            (1, SRC_LEN, cfg.d_model)).astype(np.float32)}
    if cfg.family == "vlm":
        return {"image_embeds": rng.standard_normal(
            (1, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)}
    return {}


def _serve(engine_cls, model, params, prompts, extras, max_new=3,
           **engine_kw):
    # src_len sizes the encoder-decoder cross-KV lanes; the vlm cross
    # cache sizes itself from cfg.n_image_tokens when src_len is 0
    src_len = SRC_LEN if model.cfg.encoder_decoder else 0
    eng = engine_cls(model, params, slots=2, max_len=32, src_len=src_len,
                     **engine_kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new,
                    extras=dict(extras))
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=300)
    assert all(r.done for r in reqs)
    return eng, {r.rid: r.out for r in reqs}


def _parity(arch: str, use_pallas: bool, n_prompts: int = 3,
            paged: bool = False):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, use_pallas=use_pallas)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (4, 9, 6, 17, 12)[:n_prompts]]
    extras = _extras(cfg, rng)
    kw = dict(paged=True, page_size=8) if paged else {}
    _, ref = _serve(ReferenceEngine, model, params, prompts, extras)
    eng, new = _serve(ServeEngine, model, params, prompts, extras, **kw)
    assert new == ref, (arch, use_pallas, paged)
    # the families this PR moved onto the bucket path must actually be on
    # it, and stay within the bounded-compile guarantee
    if cfg.family in ("dense", "ssm", "hybrid"):
        assert eng.bucketed
        assert eng.prefill_compiles <= eng.max_prefill_compiles
    if paged:
        eng._pool.assert_drained()
    for toks in new.values():
        assert all(0 <= t < cfg.vocab for t in toks)


@pytest.mark.slow
@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["reference", "pallas"])
@pytest.mark.parametrize("arch", MATRIX_ARCHS)
def test_family_parity_matrix(arch, use_pallas):
    """ServeEngine == ReferenceEngine, token-exact, for every family on
    both execution backends."""
    _parity(arch, use_pallas)


@pytest.mark.tier1
@pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
def test_stateful_bucketed_parity_fast(arch):
    """Fast-gate subset: the two families newly on the bucketed prefill
    path stay token-exact (jnp backend; the full matrix is `slow`)."""
    _parity(arch, use_pallas=False, n_prompts=4)


# Every bucketed-prefill family (the only ones the paged cache supports)
PAGED_ARCHS = ["granite-8b", "mamba2-370m", "hymba-1.5b"]


@pytest.mark.slow
@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["reference", "pallas"])
@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_parity_matrix(arch, use_pallas):
    """Paged column: ServeEngine(paged=True) == ReferenceEngine,
    token-exact, for every bucketed family on both backends, with the
    page pool fully drained at the end."""
    _parity(arch, use_pallas, n_prompts=5, paged=True)


@pytest.mark.tier1
@pytest.mark.parametrize("arch", ["granite-8b", "hymba-1.5b"])
def test_paged_parity_fast(arch):
    """Fast-gate subset of the paged column: one pure-attention and one
    hybrid (ring + SSM state stays lane-resident while global-attention
    KV pages)."""
    _parity(arch, use_pallas=False, n_prompts=4, paged=True)
