"""Serving execution backend tests: bucketed prefill + fused decode engine
vs the seed reference engine (the oracle), Pallas-path logits parity, the
jit-compile-count regression gate, the src_len threading regression, the
block autotuner, and the benchmark JSON schema."""

import importlib.util
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.model import Model
from repro.parallel.autoshard import choose_blocks
from repro.serve.engine import Request, ServeEngine
from repro.serve.reference import ReferenceEngine


def _setup(arch="granite-8b", seed=0, **model_kw):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, **model_kw)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _run(engine_cls, model, params, prompts, max_new=4, **kw):
    eng = engine_cls(model, params, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=500)
    assert all(r.done for r in reqs)
    return eng, {r.rid: r.out for r in reqs}


# --------------------------------------------------------------------------
# bucketed + fused engine == seed oracle
# --------------------------------------------------------------------------

def test_bucketed_engine_matches_reference_mixed_lengths():
    """Same greedy tokens from the on-device hot loop and the seed
    per-token engine, across mixed prompt lengths and buckets."""
    cfg, model, params = _setup(seed=3)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9, 3, 17, 12, 33)]
    _, ref = _run(ReferenceEngine, model, params, prompts,
                  slots=2, max_len=64)
    eng, new = _run(ServeEngine, model, params, prompts,
                    slots=2, max_len=64)
    assert eng.bucketed
    assert new == ref


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-370m"])
def test_single_slot_engine_matches_reference(arch):
    """Regression: _probe_batch_axes used to hardcode axis 0 for every
    leaf when slots == 1, scattering stacked-layer cache leaves (batch on
    axis 1) along the LAYER axis — a 1-slot engine served garbage for the
    first decode chunk while every layer past the first started from a
    zeroed prefill. The axes are now probed from 2-vs-1-lane throwaway
    trees regardless of slot count."""
    cfg, model, params = _setup(arch)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 6, dtype=np.int32)]
    _, ref = _run(ReferenceEngine, model, params, prompts, max_new=16,
                  slots=1, max_len=64)
    _, new = _run(ServeEngine, model, params, prompts, max_new=16,
                  slots=1, max_len=64)
    assert new == ref


def test_fused_decode_mixed_budgets():
    """Lanes with different budgets finish at the right lengths even when
    they share fused decode chunks."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(2)
    eng = ServeEngine(model, params, slots=3, max_len=64, decode_chunk=8)
    budgets = [2, 7, 5, 1, 9]
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4 + i,
                                               dtype=np.int32),
                    max_new_tokens=b)
            for i, b in enumerate(budgets)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=100)
    for r, b in zip(reqs, budgets):
        # seed semantics: prefill token + max(1, max_new - 1) decode steps
        assert r.done and len(r.out) == max(2, b), (r.rid, len(r.out), b)


def test_fused_decode_eos_truncates():
    """EOS inside a fused chunk stops the lane at the eos token (inclusive)
    and matches the reference engine's eos behavior."""
    cfg, model, params = _setup(seed=5)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32) for n in (6, 11)]
    _, free = _run(ReferenceEngine, model, params, prompts, max_new=8,
                   slots=2, max_len=64)
    eos = free[0][2]          # third greedy token of request 0 becomes eos
    _, ref = _run(ReferenceEngine, model, params, prompts, max_new=8,
                  slots=2, max_len=64, eos_id=eos)
    _, new = _run(ServeEngine, model, params, prompts, max_new=8,
                  slots=2, max_len=64, eos_id=eos)
    assert new == ref
    assert new[0][-1] == eos and len(new[0]) <= 3


def test_prompt_filling_cache_retires_without_decode():
    """A prompt of length max_len leaves no room for a decode append: the
    lane must retire with just the prefill token, never clobber the last
    KV slot (both engines)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, 16, dtype=np.int32),
               rng.integers(0, cfg.vocab, 5, dtype=np.int32)]
    outs = {}
    for cls in (ServeEngine, ReferenceEngine):
        _, out = _run(cls, model, params, prompts, max_new=4,
                      slots=2, max_len=16)
        assert len(out[0]) == 1          # prefill token only, cache intact
        assert len(out[1]) == 4
        outs[cls.__name__] = out
    assert outs["ServeEngine"] == outs["ReferenceEngine"]


def test_requests_with_extras_skip_the_bucket_batch():
    """extras carry per-request shapes: they must ride the exact-length
    prefill path even on a bucketed engine (never silently dropped)."""
    cfg, model, params = _setup()
    rng = np.random.default_rng(5)
    eng = ServeEngine(model, params, slots=2, max_len=32)
    assert eng.bucketed
    reqs = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, 6,
                                               dtype=np.int32),
                    max_new_tokens=3, extras={"unused": np.zeros((1, 2))}),
            Request(rid=1, prompt=rng.integers(0, cfg.vocab, 7,
                                               dtype=np.int32),
                    max_new_tokens=3)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=50)
    assert all(r.done and len(r.out) == 3 for r in reqs)
    # the extras request went down the exact-length path (recorded by
    # prompt length, not bucket)
    assert 6 in eng._buckets_seen


# --------------------------------------------------------------------------
# jit compile-count regression (the bounded-bucket guarantee)
# --------------------------------------------------------------------------

def test_prefill_compile_count_bounded():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    lengths = (3, 4, 5, 7, 9, 12, 17, 25, 31, 33, 48)   # 11 distinct
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in lengths]
    eng, _ = _run(ServeEngine, model, params, prompts, max_new=2,
                  slots=2, max_len=64)
    # bucketed prefill compiles one variant per pow2 bucket, never one per
    # prompt length: <= log2(max_len) on any workload
    assert eng.prefill_compiles <= int(math.log2(64))
    assert eng.prefill_compiles < len(set(lengths))
    # the actual jit cache (not just engine bookkeeping) is bounded too
    assert eng.prefill_compiles == len(eng._buckets_seen)


def test_decode_chunk_compile_count_bounded():
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 4 + i, dtype=np.int32)
               for i in range(6)]
    eng, _ = _run(ServeEngine, model, params, prompts, max_new=11,
                  slots=2, max_len=64, decode_chunk=8)
    # pow2-floored chunks: at most log2(decode_chunk)+1 compiled variants
    assert eng._decode_fn._cache_size() <= int(math.log2(8)) + 1


@pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
def test_stateful_prefill_compile_count_bounded(arch):
    """SSM / ring families now ride the bucketed path (masked state
    updates): their prefill jit cache must obey the same <= log2(max_len)
    bound as the dense gate, not one entry per prompt length."""
    cfg, model, params = _setup(arch)
    rng = np.random.default_rng(0)
    lengths = (3, 4, 5, 7, 9, 12, 17, 25, 31, 33, 48)   # 11 distinct
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in lengths]
    eng, _ = _run(ServeEngine, model, params, prompts, max_new=2,
                  slots=2, max_len=64)
    assert eng.bucketed
    assert eng.prefill_compiles <= int(math.log2(64))
    assert eng.prefill_compiles < len(set(lengths))
    assert eng.prefill_compiles == len(eng._buckets_seen)


# --------------------------------------------------------------------------
# src_len threading (seed regression: _prefill_into dropped src_len)
# --------------------------------------------------------------------------

def test_prefill_threads_src_len_encoder_decoder():
    cfg, model, params = _setup("whisper-small")
    src_len = 8
    eng = ServeEngine(model, params, slots=2, max_len=32, src_len=src_len)
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((1, src_len, cfg.d_model)).astype(
        np.float32)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4 + i,
                                               dtype=np.int32),
                    max_new_tokens=4, extras={"frames": frames})
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(max_steps=50)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    # the cross K/V lanes were actually written (the seed bug left the
    # batched cross cache silently untouched / shape-mismatched)
    ck = np.asarray(eng.cache["dec"]["cross"].k, np.float32)
    assert ck.shape[-3] == src_len
    assert np.abs(ck).sum() > 0


def test_reference_engine_threads_src_len_too():
    cfg, model, params = _setup("whisper-small")
    eng = ReferenceEngine(model, params, slots=2, max_len=32, src_len=8)
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((1, 8, cfg.d_model)).astype(np.float32)
    r = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 5, dtype=np.int32),
                max_new_tokens=3, extras={"frames": frames})
    eng.submit(r)
    eng.run_to_completion(max_steps=50)
    assert r.done and len(r.out) == 3
    assert np.abs(np.asarray(eng.cache["dec"]["cross"].k,
                             np.float32)).sum() > 0


# --------------------------------------------------------------------------
# use_pallas execution backend: logits parity with the reference path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-8b", "minitron-8b"])
def test_pallas_backend_logits_parity(arch):
    """Model(use_pallas=True) == reference einsum path within bf16
    accumulation noise, prefill and decode (interpret mode on CPU)."""
    cfg = reduced(get_arch(arch))
    mref = Model(cfg)
    mpal = Model(cfg, use_pallas=True)
    params = mref.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)),
                                   jnp.int32)}
    l_ref, _ = mref.forward(params, batch)
    l_pal, _ = mpal.forward(params, batch)
    scale = float(np.abs(np.asarray(l_ref, np.float32)).max())
    np.testing.assert_allclose(np.asarray(l_pal, np.float32),
                               np.asarray(l_ref, np.float32),
                               atol=0.05 * scale, rtol=0.1)

    c_ref = mref.init_cache(2, 16)
    c_pal = mpal.init_cache(2, 16)
    _, c_ref = mref.prefill(params, batch, c_ref)
    _, c_pal = mpal.prefill(params, batch, c_pal)
    tok = jnp.asarray([3, 5], jnp.int32)
    d_ref, _ = mref.decode_step(params, tok, c_ref, 8)
    d_pal, _ = mpal.decode_step(params, tok, c_pal, 8)
    np.testing.assert_allclose(np.asarray(d_pal, np.float32),
                               np.asarray(d_ref, np.float32),
                               atol=0.05 * scale, rtol=0.1)


def test_pallas_backend_serves_end_to_end():
    """The engine runs on the Pallas execution backend (interpret mode)."""
    cfg, model, params = _setup(use_pallas=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32) for n in (4, 7)]
    eng, out = _run(ServeEngine, model, params, prompts, max_new=3,
                    slots=2, max_len=32)
    for toks in out.values():
        assert len(toks) == 3
        assert all(0 <= t < cfg.vocab for t in toks)


def test_moe_decode_hot_path_runs_grouped_gemm(monkeypatch):
    """With use_pallas the MoE serving hot loop must trace the grouped
    pod kernel into both prefill and decode (no einsum dispatch): the
    grouped launches appear when each phase compiles, and the LM head
    traces the fused-lane pod GEMM."""
    import repro.kernels.systolic_gemm.ops as gops
    calls = {"grouped": 0}
    real = gops.grouped_gemm

    def counting(*a, **k):
        calls["grouped"] += 1
        return real(*a, **k)

    monkeypatch.setattr(gops, "grouped_gemm", counting)
    cfg, model, params = _setup("dbrx-132b", use_pallas=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32) for n in (4, 6)]
    _, out = _run(ServeEngine, model, params, prompts, max_new=3,
                  slots=2, max_len=32)
    # 3 launches (up/gate/down) x (prefill trace + decode-chunk traces)
    assert calls["grouped"] >= 6
    assert all(len(t) == 3 for t in out.values())


def test_tied_embedding_lm_head_runs_transposed_kernel(monkeypatch):
    """mamba2's tied embeddings route the unembed through the
    transposed-weight pod GEMM (no [d, vocab] transpose copy)."""
    import repro.kernels.systolic_gemm.ops as gops
    calls = {"nt": 0}
    real = gops.systolic_gemm_t

    def counting(*a, **k):
        calls["nt"] += 1
        return real(*a, **k)

    monkeypatch.setattr(gops, "systolic_gemm_t", counting)
    cfg, model, params = _setup("mamba2-370m", use_pallas=True)
    assert cfg.tie_embeddings
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 5, dtype=np.int32)]
    _, out = _run(ServeEngine, model, params, prompts, max_new=2,
                  slots=1, max_len=16)
    assert calls["nt"] >= 2            # prefill + decode traces
    assert len(out[0]) == 2


# --------------------------------------------------------------------------
# tile_stats-driven block autotuner
# --------------------------------------------------------------------------

def test_choose_blocks_vmem_feasible_and_cached():
    candidates = (128, 256, 512)
    before = choose_blocks.cache_info().hits
    bm, bn, bk = choose_blocks(4096, 4096, 4096)
    assert all(b in candidates for b in (bm, bn, bk))
    # VMEM working set of the chosen geometry under the 12 MiB budget
    vmem = 2 * (bm * bk + bk * bn) * 2 + bm * bn * (4 + 4)
    assert vmem <= 12 * 2 ** 20
    choose_blocks(4096, 4096, 4096)                 # per-shape cache hit
    assert choose_blocks.cache_info().hits > before


def test_choose_blocks_drives_kernel_and_stays_exact():
    """Autotuned (default) blocks must not change the GEMM result."""
    from repro.kernels.systolic_gemm.ops import systolic_gemm
    from repro.kernels.systolic_gemm.ref import systolic_gemm_ref
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-50, 50, (100, 130)), jnp.int8)
    w = jnp.asarray(rng.integers(-50, 50, (130, 70)), jnp.int8)
    out = systolic_gemm(x, w, interpret=True)       # blocks=None -> DSE
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(systolic_gemm_ref(x, w)),
                               rtol=1e-6)


def test_choose_blocks_memory_bound_prefers_wide_n():
    """A skinny decode GEMM (tiny M) is HBM-bound on activations: the
    autotuner widens block_n to cut x-block reloads."""
    bm, bn, bk = choose_blocks(8, 4096, 4096)
    assert bn >= 256


# --------------------------------------------------------------------------
# telemetry must be free: no compiles, no syncs, no token changes
# --------------------------------------------------------------------------

class _SyncCountingNumpy:
    """numpy proxy that counts device->host materializations (np.asarray
    on a jax.Array) — the engine's host-sync accounting unit."""

    def __init__(self, real):
        self._real = real
        self.syncs = 0

    def asarray(self, x, *a, **k):
        if isinstance(x, jax.Array):
            self.syncs += 1
        return self._real.asarray(x, *a, **k)

    def __getattr__(self, name):
        return getattr(self._real, name)


def test_metrics_and_tracer_add_no_compiles_or_syncs(monkeypatch):
    """The zero-overhead gate: an engine with a metrics registry and a
    span-recording tracer must produce the same tokens with the same jit
    cache sizes and the same number of host syncs as a bare engine — the
    device-side telemetry accumulators ride the existing chunk sync."""
    import repro.serve.engine as engine_mod
    from repro.obs.metrics import MetricsRegistry
    from repro.tenancy.trace import ServeTraceRecorder
    cfg, model, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9, 17, 12, 33, 7)]

    counts = {}
    outs = {}
    for name, kw in (("bare", {}),
                     ("instrumented", {"metrics": MetricsRegistry(),
                                       "tracer": ServeTraceRecorder()})):
        proxy = _SyncCountingNumpy(np)
        monkeypatch.setattr(engine_mod, "np", proxy)
        eng, out = _run(ServeEngine, model, params, prompts, max_new=5,
                        slots=2, max_len=64, decode_chunk=8, **kw)
        monkeypatch.setattr(engine_mod, "np", np)
        counts[name] = (eng._prefill_fn._cache_size(),
                        eng._decode_fn._cache_size(), proxy.syncs)
        outs[name] = out
    assert outs["instrumented"] == outs["bare"]
    assert counts["instrumented"] == counts["bare"], (
        "telemetry changed (prefill compiles, decode compiles, host syncs):"
        f" {counts}")
    # and the host genuinely synced once per device call, not per token
    eng_steps = sum(1 for _ in outs["bare"])           # lanes, not steps
    assert counts["bare"][2] < sum(len(o) for o in outs["bare"].values())
    assert eng_steps > 0


# --------------------------------------------------------------------------
# benchmark JSON schema (benchmarks/run.py --json)
# --------------------------------------------------------------------------

def _load_bench_run():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "run.py")
    spec = importlib.util.spec_from_file_location("bench_run", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_json_schema(tmp_path):
    run = _load_bench_run()
    rows = [run.parse_row("serving/decode_fused,109,tok_s=9158;p50_us=109"),
            run.parse_row("kernels/_total,123,done")]
    assert rows[0] == {"suite": "serving", "name": "serving/decode_fused",
                       "us_per_call": 109.0,
                       "derived": "tok_s=9158;p50_us=109"}
    out = tmp_path / "BENCH_test.json"
    run.write_json(rows, str(out))
    doc = json.loads(out.read_text())
    assert doc["schema"] == "sosa-bench-v1"
    assert doc["rows"][1]["suite"] == "kernels"
    assert {"suite", "name", "us_per_call", "derived"} <= set(
        doc["rows"][0])


def test_parse_row_keeps_commas_in_derived():
    """`derived` is everything past the second comma, verbatim — error
    messages (and future derived values) containing commas must survive
    the CSV round trip."""
    run = _load_bench_run()
    row = run.parse_row(
        "serving/ERROR,0,error_type=ValueError;"
        "error_msg=bad shapes (4, 8), expected (8, 4)")
    assert row["suite"] == "serving"
    assert row["us_per_call"] == 0.0
    assert row["derived"] == ("error_type=ValueError;"
                              "error_msg=bad shapes (4, 8), expected (8, 4)")


def test_error_row_carries_exception_type_and_message():
    run = _load_bench_run()
    try:
        raise RuntimeError("jit cache blew\n  past the,bound")
    except RuntimeError as e:
        line = run.error_row("serving", e)
    row = run.parse_row(line)
    assert row["name"] == "serving/ERROR"
    # type and message are greppable key=value fields; newlines flattened,
    # commas intact
    assert "error_type=RuntimeError" in row["derived"]
    assert "error_msg=jit cache blew past the,bound" in row["derived"]
    # empty-message exceptions still say something
    assert "error_msg=<no message>" in run.error_row("x", ValueError())


def test_validate_doc_catches_malformed_records():
    run = _load_bench_run()
    good = {"schema": "sosa-bench-v1", "created_unix": 1e9,
            "argv": ["--json", "x"],
            "rows": [{"suite": "s", "name": "s/a", "us_per_call": 1.0,
                      "derived": "d"},
                     {"suite": "s", "name": "s/_total", "us_per_call": 2.0,
                      "derived": "done"}]}
    assert run.validate_doc(good) == []
    assert run.validate_doc({"schema": "wrong"})       # missing everything
    bad_suite = json.loads(json.dumps(good))
    bad_suite["rows"][0]["name"] = "other/a"           # name != suite
    assert any("does not start with suite" in p
               for p in run.validate_doc(bad_suite))
    no_total = {**good, "rows": [good["rows"][0]]}
    assert any("_total" in p for p in run.validate_doc(no_total))


@pytest.mark.tier1
def test_committed_bench_records_validate():
    """Every BENCH_*.json committed at the repo root must parse against
    the sosa-bench-v1 schema (at least one must exist — the perf
    trajectory record this repo keeps across PRs)."""
    import glob
    run = _load_bench_run()
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    assert paths, "no BENCH_*.json committed at the repo root"
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        problems = run.validate_doc(doc)
        assert problems == [], f"{os.path.basename(path)}: {problems}"
        # a committed record must be a clean run: no ERROR rows
        errors = [r["name"] for r in doc["rows"]
                  if r["name"].endswith("/ERROR")]
        assert errors == [], f"{os.path.basename(path)}: {errors}"
