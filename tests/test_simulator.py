"""The analyze <-> simulate cross-check promised by core/simulator.py and
core/dse.py: the analytical wave model (the engine behind every Fig-5/Table-2
sweep) against the slice-accurate scheduler on selected Table-2 design
points.

Tolerance bands are calibrated per workload family: the wave model tracks
the scheduler within ~10% on CNN traces; on BERT traces it is optimistic by
up to ~1.55x (the scheduler pays real bank/routing conflicts on the
attention-head fan-out that the level-barrier closed form does not model).
A monolithic array (1 pod, no interconnect contention) must agree almost
exactly — there the wave model IS the schedule.
"""

import pytest

from repro.core.arrays import AcceleratorConfig, ArrayConfig
from repro.core.simulator import analyze, simulate
from repro.core.workloads import bert, resnet


def _accel(rows: int, cols: int, pods: int) -> AcceleratorConfig:
    return AcceleratorConfig(array=ArrayConfig(rows, cols), num_pods=pods,
                             icn_mw_per_byte=0.52 if pods > 1 else 0.0)


# Table-2 granularities at sim-tractable workload scale; (lo, hi) bound the
# analyze/simulate ratio for utilization (and hence effective TOPS).
PARITY_CASES = [
    # rows, cols, pods, workload, (lo, hi)
    (32, 32, 256, "bert-mini", (0.9, 1.55)),
    (64, 64, 128, "bert-mini", (0.9, 1.55)),
    (128, 128, 32, "bert-mini", (0.9, 1.6)),
    (512, 512, 1, "bert-mini", (0.999, 1.001)),
    (32, 32, 256, "resnet50", (0.8, 1.15)),
    (64, 64, 128, "resnet50", (0.8, 1.15)),
    (128, 128, 32, "resnet50", (0.8, 1.2)),
    (512, 512, 1, "resnet50", (0.999, 1.001)),
]

_WORKLOADS = {
    "bert-mini": lambda: bert("mini", 100),
    "resnet50": lambda: resnet(50, 64),
}


@pytest.mark.parametrize("rows,cols,pods,wl,band", PARITY_CASES)
def test_analyze_matches_simulate(rows, cols, pods, wl, band):
    gemms = _WORKLOADS[wl]()
    accel = _accel(rows, cols, pods)
    s = simulate(gemms, accel)
    a = analyze(gemms, accel)
    lo, hi = band

    assert a.total_macs == s.total_macs          # MAC conservation, exact
    assert a.num_tile_ops == s.num_tile_ops      # same tiling, exact
    # identical service-time model on both paths (same k_bar closed form)
    assert a.cycles_per_tile == pytest.approx(s.cycles_per_tile, rel=0.02)

    assert s.utilization > 0
    ratio_u = a.utilization / s.utilization
    assert lo < ratio_u < hi, (wl, rows, cols, ratio_u)
    ratio_e = a.effective_tops_at_tdp / s.effective_tops_at_tdp
    assert lo < ratio_e < hi, (wl, rows, cols, ratio_e)

    # analyze assumes perfect multicast reuse of X/W tiles, so its energy
    # lower-bounds the scheduler's per-op accounting — never exceeds it
    assert a.energy_joules <= s.energy_joules * 1.001
    assert a.energy_joules > 0.5 * s.energy_joules


def test_granularity_ordering_agrees_across_paths():
    """Both evaluation paths must rank the paper's headline points the same
    way: 32x32@256pods above 128x128@32pods (effective TOPS @TDP)."""
    gemms = bert("mini", 100)
    small_a = analyze(gemms, _accel(32, 32, 256))
    large_a = analyze(gemms, _accel(128, 128, 32))
    small_s = simulate(gemms, _accel(32, 32, 256))
    large_s = simulate(gemms, _accel(128, 128, 32))
    assert small_a.effective_tops_at_tdp > large_a.effective_tops_at_tdp
    assert small_s.effective_tops_at_tdp > large_s.effective_tops_at_tdp


def test_busy_pods_bounded_and_consistent():
    gemms = resnet(50, 64)
    for pods in (32, 256):
        a = analyze(gemms, _accel(32, 32, pods))
        s = simulate(gemms, _accel(32, 32, pods))
        assert 0 < a.busy_pods <= 1.0
        assert 0 < s.busy_pods <= 1.0


@pytest.mark.slow
def test_analyze_matches_simulate_bert_medium_full_point():
    """The paper's design point (32x32 x 256 pods) on a mid-size BERT —
    the heaviest cross-check (runs the full scheduler, ~10 s)."""
    gemms = bert("medium", 100)
    accel = _accel(32, 32, 256)
    s = simulate(gemms, accel)
    a = analyze(gemms, accel)
    assert a.total_macs == s.total_macs
    assert 0.9 < a.utilization / s.utilization < 1.55
