"""repro.tenancy: batched co-schedule planner vs the scalar oracle, the
Fig-11 reproduction, SliceScheduler parity, the serve-engine trace bridge,
and the two satellite models that ride the same engine (vectorized SRAM
spill, functional-router ICN calibration).

The load-bearing guarantees:
  * the whole (>= 8 designs x >= 8 mixes) grid is ONE analyze_batch call,
    and every cell matches the pure-Python merge_workloads + wave-model
    oracle (plan_mix_scalar) to float tolerance;
  * the Fig-11 co-schedule shows parallel >= sequential everywhere and
    > 1.2x at 128 pods (paper: 1.44x at 256), property-tested through the
    hypothesis fallback;
  * the planner's merged-trace makespan sits inside the calibrated
    analyze<->simulate parity bands (tests/test_simulator.py) against the
    slice-accurate SliceScheduler.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade gracefully: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (AcceleratorConfig, ArrayConfig, icn_efficiency,
                        pack_workloads, routed_fraction, simulate,
                        sram_spill_bytes)
from repro.core.simulator import _levels
from repro.core.workloads import bert, resnet
from repro.tenancy import (SPACE_SHARE, TIME_MUX, ServeTraceRecorder, Tenant,
                           TenantMix, fig11_mixes, mix_grid, pack_mixes,
                           partition_pods, plan_mix_scalar, plan_mixes,
                           plan_space_share, plan_time_mux, solo_workloads,
                           trace_tenant, trace_to_gemms)

# -- small but structurally rich mix/design grid ---------------------------

_FACTORIES = {
    "resnet50@64": lambda b: resnet(50, 64, batch=b),
    "bert-mini@40": lambda b: bert("mini", 40, batch=b),
    "bert-mini@100": lambda b: bert("mini", 100, batch=b),
    "resnet50@96": lambda b: resnet(50, 96, batch=b),
}


def _mixes8() -> list[TenantMix]:
    """12 mixes (4 choose 2 = 6 pairs x 2 batches) — >= the 8 the
    acceptance grid requires."""
    return mix_grid(_FACTORIES, batches=(1, 2), pair_size=2)


def _designs8():
    """8 design points mixing granularity, fabric, and isopower pods."""
    return [
        (16, 16, "butterfly-2", 256),
        (32, 32, "butterfly-2", 64),
        (32, 32, "butterfly-2", 256),
        (32, 32, "butterfly-1", 128),
        (64, 64, "butterfly-2", 64),
        (64, 64, "crossbar", None),
        (128, 128, "butterfly-2", None),
        (32, 64, "benes", 128),
    ]


# --------------------------------------------------------------------------
# batched grid == scalar merge_workloads + analyze oracle
# --------------------------------------------------------------------------


def test_grid_is_one_analyze_batch_call(monkeypatch):
    """>= (8 designs x 8 mixes) through exactly one analyze_batch call."""
    import repro.tenancy.planner as planner_mod
    calls = []
    real = planner_mod.analyze_batch

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(planner_mod, "analyze_batch", counting)
    mixes, designs = _mixes8(), _designs8()
    grid = planner_mod.plan_time_mux(mixes, designs)
    assert len(calls) == 1
    assert len(grid) == len(designs) >= 8
    assert all(len(row) == len(mixes) >= 8 for row in grid)


def test_batched_grid_matches_scalar_oracle():
    """Every cell of the batched grid equals the pure-Python oracle."""
    mixes, designs = _mixes8(), _designs8()
    grid = plan_time_mux(mixes, designs)
    for p, design in enumerate(designs):
        for m, mix in enumerate(mixes):
            b = grid[p][m]
            s = plan_mix_scalar(mix, design)
            assert (b.rows, b.cols, b.num_pods) == (s.rows, s.cols, s.num_pods)
            for f in ("makespan_s", "utilization", "effective_tops_at_tdp",
                      "sequential_effective_tops"):
                assert getattr(b, f) == pytest.approx(
                    getattr(s, f), rel=1e-9), (f, design, mix.name)
            for sb, ss in zip(b.streams, s.streams):
                assert sb.tenant == ss.tenant
                assert sb.latency_s == pytest.approx(ss.latency_s, rel=1e-9)
                assert sb.solo_latency_s == pytest.approx(
                    ss.solo_latency_s, rel=1e-9)
            assert b.fairness == pytest.approx(s.fairness, rel=1e-9)


def test_stream_latencies_bounded_by_makespan():
    mixes, designs = _mixes8(), _designs8()
    grid = plan_time_mux(mixes, designs)
    for row in grid:
        for plan in row:
            for s in plan.streams:
                assert 0 < s.latency_s <= plan.makespan_s * (1 + 1e-12)
                assert s.slowdown >= 1.0 - 1e-12
            # deepest stream drains last: its latency IS the makespan
            assert max(s.latency_s for s in plan.streams) == pytest.approx(
                plan.makespan_s, rel=1e-12)


# --------------------------------------------------------------------------
# Fig 11: parallel >= sequential, > 1.2x at 128 pods
# --------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(pods=st.sampled_from([64, 128, 256, 512]),
       gran=st.sampled_from([16, 32, 64]),
       batch=st.sampled_from([1, 2, 4]))
def test_fig11_parallel_geq_sequential(pods, gran, batch):
    """Co-scheduling the Fig-11 pair never loses to back-to-back solo runs
    anywhere in the (pods x granularity x batch) space."""
    mixes = fig11_mixes(batches=(batch,))
    plan = plan_time_mux(mixes, [(gran, gran, "butterfly-2", pods)])[0][0]
    assert plan.parallel_gain >= 1.0 - 1e-9
    assert plan.slo_attainment == 1.0          # no SLOs declared
    assert 0 < plan.fairness <= 1.0 + 1e-12


def test_fig11_gain_at_128_pods():
    """The acceptance cell: paper-direction gain (> 1.2x) on 128 pods at
    batch 1, growing with pod count, shrinking with batch (Fig 11)."""
    grid = plan_time_mux(fig11_mixes(batches=(1, 2, 4, 8)),
                         [(32, 32, "butterfly-2", 128),
                          (32, 32, "butterfly-2", 256)])
    g128 = [plan.parallel_gain for plan in grid[0]]
    g256 = [plan.parallel_gain for plan in grid[1]]
    assert g128[0] > 1.2
    assert g256[0] > g128[0]                   # more pods, more idle to win
    assert g128 == sorted(g128, reverse=True)  # batching erodes the gain
    assert g256 == sorted(g256, reverse=True)


# --------------------------------------------------------------------------
# planner vs the slice-accurate SliceScheduler (calibrated bands)
# --------------------------------------------------------------------------


def _parity_mix(image: int, seq: int) -> TenantMix:
    return TenantMix(name="parity", tenants=(
        Tenant(name="rn", gemms=tuple(resnet(50, image))),
        Tenant(name="bt", gemms=tuple(bert("mini", seq)), replicas=2)))


def _parity_check(mix: TenantMix, pods: int, band: tuple[float, float]):
    accel = AcceleratorConfig(array=ArrayConfig(32, 32), num_pods=pods)
    s = simulate(mix.merged(), accel)
    plan = plan_time_mux([mix], [(32, 32, "butterfly-2", pods)])[0][0]
    util_a = plan.utilization
    lo, hi = band
    assert lo < util_a / s.utilization < hi, util_a / s.utilization
    # same headline metric on both paths
    eff_s = s.effective_tops_at_tdp
    assert lo < plan.effective_tops_at_tdp / eff_s < hi


def test_planner_matches_slice_scheduler_small():
    """Merged-graph parity at sim-tractable scale: same bands as the
    analyze<->simulate suite (BERT-optimistic up to ~1.55x)."""
    _parity_check(_parity_mix(64, 40), pods=64, band=(0.8, 1.55))


@pytest.mark.slow
def test_planner_matches_slice_scheduler_fig11_scale():
    """The Fig-11-shaped co-schedule against the full scheduler (~10 s)."""
    _parity_check(_parity_mix(96, 100), pods=128, band=(0.8, 1.55))


# --------------------------------------------------------------------------
# space-shared policy
# --------------------------------------------------------------------------


def test_partition_pods_properties():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(2 ** rng.integers(2, 9))
        k = int(rng.integers(1, min(n, 6) + 1))
        macs = rng.integers(1, 10 ** 9, size=k).astype(float)
        pods = partition_pods(n, macs)
        assert pods.sum() <= n
        assert (pods >= 1).all()
        assert all((p & (p - 1)) == 0 for p in pods)  # powers of two
    with pytest.raises(ValueError):
        partition_pods(2, np.ones(3))


def test_space_share_plan_invariants():
    mixes = fig11_mixes(batches=(1,))
    designs = [(32, 32, "butterfly-2", 128), (32, 32, "butterfly-2", 256)]
    grid = plan_space_share(mixes, designs)
    for row, pods in zip(grid, (128, 256)):
        plan = row[0]
        assert plan.policy == SPACE_SHARE
        assert sum(s.pods for s in plan.streams) <= pods
        for s in plan.streams:
            # a partition slice can only slow a stream down vs full machine
            assert s.slowdown >= 1.0 - 1e-9
        assert plan.makespan_s == pytest.approx(
            max(s.latency_s for s in plan.streams), rel=1e-12)
    # the classic trade-off on this mix: time-mux wins throughput,
    # space-share wins fairness (isolation)
    tm = plan_mixes(mixes, designs[1:], policy=TIME_MUX)[0][0]
    ss = grid[1][0]
    assert tm.effective_tops_at_tdp > ss.effective_tops_at_tdp
    assert ss.fairness > tm.fairness


def test_slo_attainment_reported():
    tight, loose = 1e-7, 10.0
    mix = TenantMix(name="slo", tenants=(
        Tenant(name="rn", gemms=tuple(resnet(50, 64)), slo_latency_s=loose),
        Tenant(name="bt", gemms=tuple(bert("mini", 40)),
               slo_latency_s=tight)))
    plan = plan_time_mux([mix], [(32, 32, "butterfly-2", 64)])[0][0]
    met = {s.tenant: s.slo_met for s in plan.streams}
    assert met["rn"] is True and met["bt"] is False
    assert plan.slo_attainment == 0.5


# --------------------------------------------------------------------------
# serve-engine trace bridge
# --------------------------------------------------------------------------


def test_trace_bridge_synthetic_events():
    from repro.configs import get_arch, reduced
    cfg = reduced(get_arch("granite-8b"))
    rec = ServeTraceRecorder()
    rec.on_prefill(0, 12)
    rec.on_decode(1, [12])
    rec.on_prefill(1, 7)
    rec.on_decode(2, [13, 7])
    gemms = trace_to_gemms(rec, cfg)
    # 8 GEMMs per layer per event (qkv + qk/av + o + 2 ffn)
    assert len(gemms) == 4 * cfg.n_layers * 8
    # prefill rows = prompt len; fused decode rows = live lanes
    assert gemms[0].d1 == 12
    d1s = [g.d1 for g in gemms if g.name == "q"]
    assert d1s == [12] * cfg.n_layers + [1] * cfg.n_layers \
        + [7] * cfg.n_layers + [2] * cfg.n_layers
    # events chain: a valid dependency order with increasing gemm ids
    by_id = {g.gemm_id: g for g in gemms}
    for g in gemms:
        assert all(d in by_id and d < g.gemm_id for d in g.depends_on)
    t = trace_tenant("serve", rec, cfg, slo_latency_s=1e-3)
    plan = plan_time_mux(
        [TenantMix(name="serve+rn", tenants=(
            t, Tenant(name="rn", gemms=tuple(resnet(50, 64)))))],
        [(32, 32, "butterfly-2", 64)])[0][0]
    assert plan.parallel_gain >= 1.0 - 1e-9
    assert {s.tenant for s in plan.streams} == {"serve", "rn"}


def test_trace_bridge_records_live_engine():
    """The engine's actual continuous-batching timeline drives the planner
    (serve/engine.py tracer hook -> tenancy/trace.py -> planner)."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_arch, reduced
    from repro.models.model import Model
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced(get_arch("granite-8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rec = ServeTraceRecorder()
    engine = ServeEngine(model, params, slots=2, max_len=32, tracer=rec)
    rng = np.random.default_rng(0)
    for i in range(3):
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab, 4 + 2 * i,
                                                  dtype=np.int32),
                              max_new_tokens=3))
    engine.run_to_completion(max_steps=50)
    assert rec.num_prefills == 3
    assert rec.num_decode_steps >= 3
    # decode events saw fused lanes (continuous batching), never > slots
    lanes = [e[1] for e in rec.events if e[0] == "decode"]
    assert max(lanes) <= 2 and max(lanes) == 2
    tnt = trace_tenant("lm", rec, cfg)
    assert tnt.macs > 0 and tnt.depth > 1


def test_trace_tenant_rejects_empty_recorder():
    from repro.configs import get_arch, reduced
    with pytest.raises(ValueError):
        trace_tenant("empty", ServeTraceRecorder(),
                     reduced(get_arch("granite-8b")))


def test_trace_tenant_error_names_kwarg_and_missing_phase():
    """The empty-trace error must tell the user HOW to fix it (the
    `tracer` engine kwarg) and WHICH phase is missing."""
    from repro.configs import get_arch, reduced
    cfg = reduced(get_arch("granite-8b"))
    with pytest.raises(ValueError) as ei:
        trace_tenant("svc", ServeTraceRecorder(), cfg)
    msg = str(ei.value)
    assert "'svc'" in msg
    assert "tracer" in msg and "ServeEngine" in msg
    assert "prefill/decode" in msg            # both phases missing
    assert "none" in msg                      # nothing recorded at all

    # a prefill-only trace asked for decode events names just the gap
    rec = ServeTraceRecorder()
    rec.on_prefill(0, 8)
    with pytest.raises(ValueError) as ei:
        trace_tenant("svc", rec, cfg, kinds=("decode",))
    msg = str(ei.value)
    assert "no decode events" in msg
    assert "prefill" in msg                   # what WAS recorded, listed


# --------------------------------------------------------------------------
# mix construction invariants
# --------------------------------------------------------------------------


def test_mix_grid_and_pack_shapes():
    mixes = _mixes8()
    assert len(mixes) == 12
    packed = pack_mixes(mixes)
    assert packed.num_workloads == 12
    # merged mix MACs = sum of replica-stream MACs
    for m, mix in enumerate(mixes):
        g0 = packed.wl_gemm_starts[m]
        g1 = packed.wl_gemm_starts[m + 1] if m + 1 < len(mixes) \
            else len(packed.d1)
        assert int(packed.macs[g0:g1].sum()) == mix.total_macs


def test_mix_validation_errors():
    rn = tuple(resnet(50, 64))
    with pytest.raises(ValueError):
        Tenant(name="x", gemms=())
    with pytest.raises(ValueError):
        Tenant(name="x", gemms=rn, replicas=0)
    with pytest.raises(ValueError):
        TenantMix(name="m", tenants=())
    m = TenantMix(name="m", tenants=(Tenant(name="x", gemms=rn),))
    with pytest.raises(ValueError):
        pack_mixes([m, m])
    # same tenant name, different trace -> solo baseline would be ambiguous
    m2 = TenantMix(name="m2", tenants=(
        Tenant(name="x", gemms=tuple(bert("mini", 40))),))
    with pytest.raises(ValueError):
        solo_workloads([m, m2])


# --------------------------------------------------------------------------
# satellite: vectorized SRAM spill == the scalar per-level loop
# --------------------------------------------------------------------------


def test_sram_spill_matches_scalar_loop():
    suite = {"rn": resnet(50, 128, batch=2), "bt": bert("mini", 100)}
    packed = pack_workloads(suite)
    caps = np.array([0.5e6, 2e6, 8e6, 64e6])
    got = sram_spill_bytes(packed, caps)
    assert got.shape == (len(caps), len(suite))
    for w, (name, wl) in enumerate(suite.items()):
        for b, cap in enumerate(caps):
            spill = 0.0
            for level in _levels(wl):
                ws = sum(g.d1 * g.d2 + 2 * g.d2 * g.d3 + 2 * g.d1 * g.d3
                         for g in level)
                spill += max(0.0, ws - cap)
            assert got[b, w] == pytest.approx(spill, rel=1e-12), (name, cap)
    # monotone: more SRAM never spills more
    assert (np.diff(got, axis=0) <= 0).all()


# --------------------------------------------------------------------------
# satellite: ICN efficiency calibrated from the functional router
# --------------------------------------------------------------------------


def test_icn_efficiency_calibrated_within_5pct_of_table1():
    """The analytical model's Butterfly-1 busy-pod penalty now comes from
    greedy functional routing of sampled permutations (with the
    scheduler's 8-candidate search), not the hardcoded Table-1 ratio —
    pinned to within 5% of the paper's 66.81/72.41."""
    calibrated = icn_efficiency("butterfly-1")
    paper = 66.81 / 72.41
    assert abs(calibrated - paper) / paper < 0.05
    assert calibrated < 1.0                      # it must cost something
    # cached: second call returns the identical object value
    assert icn_efficiency("butterfly-1") == calibrated
    # full-permutation fabrics pay nothing, by construction and by measure
    assert icn_efficiency("crossbar") == 1.0
    assert routed_fraction("crossbar") == 1.0
    assert routed_fraction("benes") == 1.0


def test_routed_fraction_monotone_in_expansion():
    """More expansion planes can only route more of a permutation."""
    f1 = routed_fraction("butterfly-1", ports=64, samples=4)
    f2 = routed_fraction("butterfly-2", ports=64, samples=4)
    f4 = routed_fraction("butterfly-4", ports=64, samples=4)
    assert 0 < f1 <= f2 <= f4 <= 1.0
