"""Training stack + serving engine integration tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.data import DataConfig, batches
from repro.train.fault import ElasticMesh, Heartbeat, StragglerPolicy
from repro.train.optimizer import AdamWConfig, init_adamw, lr_schedule
from repro.train.train_step import TrainConfig, make_train_step


def _setup(arch="granite-8b", seed=0):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, remat=True)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def test_loss_decreases_over_steps():
    cfg, model, params = _setup()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr_peak=5e-3, warmup_steps=3,
                                             total_steps=60,
                                             weight_decay=0.0))
    step_fn = jax.jit(make_train_step(model, tcfg))
    opt = init_adamw(params)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    stream = batches(dcfg)
    losses = []
    for _ in range(30):
        b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step_fn(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


def test_microbatched_grads_match_full_batch():
    cfg, model, params = _setup()
    from repro.train.train_step import grads_fn
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    b = {k: jnp.asarray(v) for k, v in next(batches(dcfg)).items()}
    l1, g1 = grads_fn(model, TrainConfig(microbatches=1))(params, b)
    l2, g2 = grads_fn(model, TrainConfig(microbatches=4))(params, b)
    assert abs(float(l1) - float(l2)) < 0.05
    flat1, flat2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    rel = max(float(jnp.abs(a.astype(jnp.float32)
                            - b_.astype(jnp.float32)).max())
              for a, b_ in zip(flat1, flat2))
    assert rel < 0.1, rel


def test_lr_schedule_shape():
    c = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(c, jnp.asarray(0))) < 1e-4
    assert abs(float(lr_schedule(c, jnp.asarray(10))) - 1e-3) < 1e-4
    assert float(lr_schedule(c, jnp.asarray(100))) < 2.1e-4


def test_checkpoint_roundtrip_and_atomicity():
    cfg, model, params = _setup()
    opt = init_adamw(params)
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 10, (params, opt))
        save_checkpoint(d, 20, (params, opt))
        # torn checkpoint (no COMMITTED) must be ignored
        os.makedirs(os.path.join(d, "step_00000030"))
        assert latest_step(d) == 20
        (p2, o2), step = restore_checkpoint(d, (params, opt))
        assert step == 20
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_stream_deterministic_resume():
    dcfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    s1 = batches(dcfg, start_step=0)
    for _ in range(5):
        next(s1)
    b5 = next(s1)
    b5_resumed = next(batches(dcfg, start_step=5))
    np.testing.assert_array_equal(b5["tokens"], b5_resumed["tokens"])


def test_fault_heartbeat_and_straggler():
    hb = Heartbeat(num_hosts=4, timeout_steps=2)
    for h in range(4):
        hb.beat(h, 10)
    hb.beat(0, 13)
    hb.beat(1, 13)
    hb.beat(2, 13)
    assert hb.dead_hosts(13) == [3]

    sp = StragglerPolicy(slow_factor=2.0, patience=2)
    for step in range(3):
        for h in range(4):
            sp.observe(h, 1.0 if h != 2 else 5.0)
        stragglers = sp.stragglers()
    assert 2 in stragglers


def test_elastic_remesh_preserves_tp():
    em = ElasticMesh(total_hosts=512, tp_degree=16, hosts_per_pod=256)
    m0 = em.next_mesh()
    assert m0["model"] == 16
    assert m0["pod"] * m0["data"] * m0["model"] == 512
    em.fail(17)
    m1 = em.next_mesh()
    assert m1["model"] == 16
    assert m1["pod"] * m1["data"] * m1["model"] == 256  # pow2 fallback
    assert em.microbatch_scale(original_dp=32) == 2


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-370m", "hymba-1.5b"])
def test_serve_engine_continuous_batching(arch):
    cfg, model, params = _setup(arch)
    engine = ServeEngine(model, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 4 + 3 * i,
                                        dtype=np.int32),
                    max_new_tokens=5)
            for i in range(4)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion(max_steps=200)
    for r in reqs:
        assert r.done
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_serve_engine_matches_single_request_decode():
    """A request decoded inside a mixed batch must equal its solo decode."""
    cfg, model, params = _setup("granite-8b", seed=3)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 9)]

    def solo(prompt):
        cache = model.init_cache(1, 64)
        logits, cache = model.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, cache)
        out = [int(jnp.argmax(logits[0]))]
        for i in range(3):
            logits, cache = model.decode_step(
                params, jnp.asarray([out[-1]]), cache, len(prompt) + i)
            out.append(int(jnp.argmax(logits[0])))
        return out

    expected = [solo(p) for p in prompts]
    engine = ServeEngine(model, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_to_completion(max_steps=50)
    for r, exp in zip(reqs, expected):
        assert r.out == exp, (r.rid, r.out, exp)
